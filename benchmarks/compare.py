"""Bench-regression gate: compare a fresh benchmarks/run.py --json dump
against the committed baseline (BENCH_serving.json at the repo root).

CPU wall-clock is not comparable across CI machines, so throughput gates
on the *normalized* tokens/s of each serving row — its ratio to the same
file's `serving/rectangular_serialized` row, which cancels machine speed
and leaves the scheduling/overlap win the row is meant to protect.
Deterministic metrics (lane occupancy, kernel HBM-byte ratios, kernel
max-abs error) gate directly. A baseline row that is missing or skipped
in the fresh run fails the gate: the canonical row set is part of the
contract (run the gate under
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the mesh row
exists).

Usage (CI):
    python benchmarks/run.py --fast --json bench-fresh.json
    python benchmarks/compare.py BENCH_serving.json bench-fresh.json \
        --threshold 0.20

Wall-clock metrics are best-of-5 over O(100ms+) drives, which bounds the
observed run-to-run spread of the normalized ratios well inside the 20%
threshold; a *marginal* failure on a tok_s_rel row is still more likely
scheduler jitter than a real regression — re-run the job once before
hunting a culprit, and refresh the baseline (run.py --baseline) when an
intentional change moves the trajectory.
"""

from __future__ import annotations

import argparse
import json
import re
import sys

_NUM = re.compile(r"([A-Za-z_][A-Za-z0-9_]*)=(-?[0-9.]+(?:e-?[0-9]+)?)\b")

RECTANGULAR = "serving/rectangular_serialized"


def _metrics(derived):
    """{metric: float} parsed from a row's derived string."""
    return {k: float(v) for k, v in _NUM.findall(derived)}


def load(path):
    """{row name: (derived string, {metric: float})} from a --json dump."""
    with open(path) as f:
        rows = json.load(f)
    return {row["name"]: (row["derived"], _metrics(row["derived"])) for row in rows}


def norm_tok_s(table, name):
    """tokens/s of `name` relative to the rectangular-serialized row of the
    same file (machine-speed cancels). Returns None when the row's tok_s
    *or the anchor* is absent — an absolute tok/s would silently compare
    across machine speeds, so callers must skip the normalized gate."""
    tok_s = table[name][1].get("tok_s")
    anchor = table.get(RECTANGULAR, ("", {}))[1].get("tok_s")
    if tok_s is None or not anchor:
        return None
    return tok_s / anchor


def compare(base, fresh, threshold):
    """Yield (row, metric, baseline value, fresh value, ok) judgements."""
    for name, (derived, metrics) in sorted(base.items()):
        if name not in fresh:
            yield name, "present", 1.0, 0.0, False
            continue
        f_derived, f_metrics = fresh[name]
        if "skipped=" in f_derived and "skipped=" not in derived:
            yield name, "present", 1.0, 0.0, False
            continue
        if name.startswith("serving/") and name != RECTANGULAR:
            b, f = norm_tok_s(base, name), norm_tok_s(fresh, name)
            if b is not None and f is not None:
                yield name, "tok_s_rel", b, f, f >= b * (1 - threshold)
            elif (
                metrics.get("tok_s") is not None
                and f_metrics.get("tok_s") is not None
            ):
                # the row has throughput on both sides but the rectangular
                # anchor is missing from at least one file: skip the
                # normalized gate loudly instead of comparing absolute
                # tok/s across machine speeds
                side = "baseline" if b is None else "fresh run"
                if b is None and f is None:
                    side = "both files"
                print(
                    f"note: {name}: rectangular anchor row "
                    f"({RECTANGULAR!r}) absent from {side}; skipping the "
                    "normalized tok/s gate",
                    file=sys.stderr,
                )
            b, f = metrics.get("occupancy"), f_metrics.get("occupancy")
            if b is not None and f is not None:
                yield name, "occupancy", b, f, f >= b * (1 - threshold)
            # paged-cache gates: pool_util rising means the allocator
            # reserves more pages for the same trace (page leak, sharing
            # regression, over-reservation); prefill_saved falling means
            # prefix sharing stopped deduplicating prompt pages. The
            # traces are deterministic, so both are tight.
            b, f = metrics.get("pool_util"), f_metrics.get("pool_util")
            if b is not None and f is not None:
                yield name, "pool_util", b, f, f <= b * (1 + threshold)
            b, f = metrics.get("prefill_saved"), f_metrics.get("prefill_saved")
            if b is not None and f is not None:
                yield name, "prefill_saved", b, f, f >= b * (1 - threshold)
        b, f = metrics.get("hbm_bytes_ratio"), f_metrics.get("hbm_bytes_ratio")
        if b is not None and f is not None:
            yield name, "hbm_bytes_ratio", b, f, f <= b * 1.01
        b, f = metrics.get("max_abs_err"), f_metrics.get("max_abs_err")
        if b is not None and f is not None:
            yield name, "max_abs_err", b, f, f <= max(b * 10.0, 1e-5)
        # greedy-token identity of the int8 engine vs the fp engine: an
        # absolute drift bound, not relative — the metric is a fraction
        # in [0, 1] and the committed value is the fidelity contract
        b, f = metrics.get("token_match"), f_metrics.get("token_match")
        if b is not None and f is not None:
            yield name, "token_match", b, f, f >= b - 0.05
        # teacher-forced perplexity (quality benches): upward drift beyond
        # the threshold means the approximation quality regressed — lower
        # is always better, so only the increase direction gates
        b, f = metrics.get("ppl"), f_metrics.get("ppl")
        if b is not None and f is not None:
            yield name, "ppl", b, f, f <= b * (1 + threshold)
        # greedy next-token accuracy is a fraction in [0, 1]: absolute
        # drift bound, like token_match
        b, f = metrics.get("acc"), f_metrics.get("acc")
        if b is not None and f is not None:
            yield name, "acc", b, f, f >= b - 0.05

    # interleaving contract — judged *within the fresh dump* so machine
    # speed cancels: the chunked-prefill row must cut the tail inter-token
    # latency of the monolithic-admission row on the same trace without
    # giving up throughput. (The per-row tok_s_rel gates above still judge
    # both rows against the committed trajectory.)
    mono = fresh.get("serving/interleave-monolithic", ("", {}))[1]
    chunk = fresh.get("serving/interleave-chunked", ("", {}))[1]
    if mono.get("p99_itl_ms") is not None \
            and chunk.get("p99_itl_ms") is not None:
        b, f = mono["p99_itl_ms"], chunk["p99_itl_ms"]
        yield "serving/interleave-chunked", "p99_itl_vs_mono", b, f, f <= b
        b, f = mono.get("slo_miss"), chunk.get("slo_miss")
        if b is not None and f is not None:
            yield "serving/interleave-chunked", "slo_miss_vs_mono", b, f, \
                f <= b
        b, f = mono.get("tok_s"), chunk.get("tok_s")
        if b is not None and f is not None:
            yield "serving/interleave-chunked", "tok_s_vs_mono", b, f, \
                f >= b * (1 - threshold)

    # hierarchical long-context contract — judged *within the fresh dump*
    # (the byte rows are structural, so no machine-speed question, but the
    # contract relates rows to each other): at every context length the
    # hierarchical decode row must stream no more than keep_ratio × the
    # paged row's bytes (2% slack covers the kept_pages ceil + pin floor),
    # and sweeping the ratio down must monotonically shrink gated bytes.
    hier_pat = re.compile(r"^lc/decode_hier@([0-9]+k)_r[0-9.]+$")
    by_tag = {}
    for name, (_, metrics) in fresh.items():
        m = hier_pat.match(name)
        if m and "keep_ratio" in metrics and "bytes_per_tok" in metrics:
            by_tag.setdefault(m.group(1), []).append(
                (name, metrics["keep_ratio"], metrics["bytes_per_tok"]))
    for tag, hier_rows in sorted(by_tag.items()):
        paged = fresh.get(f"lc/decode_paged@{tag}", ("", {}))[1]
        pb = paged.get("bytes_per_tok")
        if pb is None:
            yield f"lc/decode_paged@{tag}", "present", 1.0, 0.0, False
            continue
        for name, ratio, bytes_tok in hier_rows:
            yield name, "bytes_vs_paged", ratio * pb, bytes_tok, \
                bytes_tok <= ratio * pb * 1.02
        sweep = sorted(hier_rows, key=lambda r: -r[1])   # ratio descending
        for (an, ar, ab), (bn, br, bb) in zip(sweep, sweep[1:]):
            yield bn, f"monotone_vs_r{ar}", ab, bb, bb < ab


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed baseline (BENCH_serving.json)")
    ap.add_argument("fresh", help="fresh --json dump to judge")
    ap.add_argument("--threshold", type=float, default=0.20)
    args = ap.parse_args(argv)

    base, fresh = load(args.baseline), load(args.fresh)
    checks = 0
    failed = []
    for name, metric, b, f, ok in compare(base, fresh, args.threshold):
        mark = "ok        " if ok else "REGRESSION"
        print(f"{mark}  {name:40s} {metric:16s} base={b:.4g} fresh={f:.4g}")
        checks += 1
        if not ok:
            failed.append((name, metric, b, f))
    if failed:
        # the exit summary names every failed gate so a CI log tail is
        # enough to see WHAT regressed, not just that something did
        print(f"{len(failed)}/{checks} checks beyond threshold "
              f"{args.threshold}:")
        for name, metric, b, f in failed:
            print(f"  FAILED {name}: {metric} "
                  f"(base={b:.4g} fresh={f:.4g})")
        sys.exit(1)
    print(f"bench gate green: {checks} checks over {len(base)} baseline rows")


if __name__ == "__main__":
    main()
