"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call is CPU wall time of
the jitted callable where meaningful, 0.0 for pure-metric rows; derived
carries the paper metric). Roofline terms come from the dry-run artifacts
via benchmarks.roofline, not from CPU timing.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import fidelity
    benches = [
        fidelity.fig2_info_retention,
        fidelity.table1_standalone,
        fidelity.table2_aqua_h2o,
        fidelity.table3_aqua_memory,
        fidelity.breakeven,
        fidelity.block_granularity,
        fidelity.kernel_bandwidth,
    ]
    print("name,us_per_call,derived")
    failures = 0
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}")
        except Exception:
            failures += 1
            print(f"{bench.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
