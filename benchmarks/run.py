"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call is CPU wall time of
the jitted callable where meaningful, 0.0 for pure-metric rows; derived
carries the paper metric). Roofline terms come from the dry-run artifacts
via benchmarks.roofline, not from CPU timing.

``--fast`` runs the CI-smoke subset: the trained-model-free benches plus
the quality sweeps (which reuse one cached trained model, see
benchmarks.common); ``--json PATH`` additionally writes the rows as a
JSON list of
``{"name", "us_per_call", "derived"}`` objects (uploaded as a CI
artifact).

``--baseline`` refreshes the committed bench-trajectory baseline: it
implies ``--fast`` and writes the canonical ``BENCH_serving.json`` at the
repo root (run under XLA_FLAGS=--xla_force_host_platform_device_count=8
so the mesh-serving rows — including the shard_mapped AQUA block-sparse
kernel rows ``serving/aqua-*@mesh2x2`` and
``prefill/aqua_block_sparse@mesh2x2`` — are measured rather than emitted
as skipped sentinels, then commit the diff; CI's ``benchmarks/compare.py``
gate judges every PR against it).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

# self-sufficient when invoked as `python benchmarks/run.py`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--fast", action="store_true", help="trained-model-free subset (CI smoke)"
    )
    ap.add_argument(
        "--json", default=None, metavar="PATH", help="also write rows as JSON to PATH"
    )
    ap.add_argument(
        "--baseline",
        action="store_true",
        help="refresh the committed BENCH_serving.json (implies --fast)",
    )
    args = ap.parse_args(argv)
    if args.baseline:
        args.fast = True
        args.json = os.path.join(_ROOT, "BENCH_serving.json")

    from benchmarks import fidelity, quality
    fast_benches = [
        fidelity.breakeven,
        fidelity.prefill_backends,
        fidelity.kernel_bandwidth,
        fidelity.quant_fidelity,
        fidelity.serving_throughput,
        fidelity.longcontext_bench,
        quality.quality_sweep,
        quality.hf_ingest_quality,
    ]
    full_benches = [
        fidelity.fig2_info_retention,
        fidelity.table1_standalone,
        fidelity.table2_aqua_h2o,
        fidelity.table3_aqua_memory,
        *fast_benches,
        fidelity.block_granularity,
    ]
    benches = fast_benches if args.fast else full_benches

    print("name,us_per_call,derived")
    rows = []
    failures = 0
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}")
                rows.append({"name": name, "us_per_call": us, "derived": derived})
        except Exception:
            failures += 1
            print(f"{bench.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
