"""Quality benches: teacher-forced perplexity + greedy-agreement sweeps.

Kernel-level error norms answer the wrong question for a deployment; what
matters is whether the model still assigns the same probabilities to
held-out text and still emits the same greedy tokens. Two benches:

``quality_sweep`` — the cached trained bench model, k_ratio swept over
{1.0, 0.75, 0.5} with the *calibrated* projections: teacher-forced
perplexity, next-token accuracy, and serving greedy token agreement vs
the exact engine, plus int8-pool and hierarchical composition rows at
k=0.5 (the two approximations share the quality budget, so they are
measured jointly — paper §7 composition note).

``hf_ingest_quality`` — the zero-network real-weights path end to end:
synthetic HF fixture (sharded safetensors, genuine HF layout) → config +
weights via ``repro.checkpoint.hf`` → offline SVD calibration over the
committed real-text corpus (``corpora/calibration.txt``, byte-level) →
teacher-forced ppl on held-out corpus windows per k_ratio, and greedy
token agreement served through the *paged 2x2-mesh* engine with a
plan-asserted kernel path (sentinel rows below 4 devices).

``ppl=`` rows gate in benchmarks/compare.py as fresh <= base*(1+thr);
``token_match=``/``acc=`` rows gate absolutely (>= base - 0.05).
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import List, Tuple

import jax
import numpy as np

from benchmarks.common import data_config, get_trained_model
from repro.configs.base import (AquaConfig, CacheSpec, QuantSpec,
                                ServingConfig, SparsitySpec)
from repro.data.pipeline import DataConfig, make_batch
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, poisson_trace

Row = Tuple[str, float, str]

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CALIBRATION_CORPUS = os.path.join(_ROOT, "corpora", "calibration.txt")


# ---------------------------------------------------------------------------
# Metric helpers (public: the oracle tests pin these against numpy)
# ---------------------------------------------------------------------------


def ppl_and_accuracy(cfg, params, proj, batches) -> Tuple[float, float]:
    """Teacher-forced perplexity + greedy next-token accuracy.

    Feeds each batch through ``model.forward`` under ``cfg`` (AQUA
    approximation included when ``cfg.aqua``/``proj`` are set), reads the
    log-probability of every label token, and averages in float64 —
    ``exp(mean NLL)``. ``loss_mask`` restricts both metrics when present.
    """
    model = build_model(cfg)
    p_arr = None if proj is None else proj.p
    fwd = jax.jit(
        lambda pr, toks: model.forward(pr, {"tokens": toks}, aqua_proj=p_arr))
    nll_sum, hits, count = 0.0, 0.0, 0.0
    for b in batches:
        logits = np.asarray(fwd(params, b["tokens"]), np.float64)
        labels = np.asarray(b["labels"])
        m = logits.max(-1, keepdims=True)
        logz = m + np.log(np.exp(logits - m).sum(-1, keepdims=True))
        ll = np.take_along_axis(logits - logz, labels[..., None], -1)[..., 0]
        mask = (np.asarray(b["loss_mask"], np.float64)
                if "loss_mask" in b else np.ones(labels.shape))
        nll_sum += float(-(ll * mask).sum())
        hits += float(((logits.argmax(-1) == labels) * mask).sum())
        count += float(mask.sum())
    return float(np.exp(nll_sum / count)), float(hits / count)


def teacher_forced_ppl(cfg, params, proj, batches) -> float:
    return ppl_and_accuracy(cfg, params, proj, batches)[0]


def match_fraction(outs, ref) -> float:
    """Fraction of greedy token positions agreeing with the reference
    engine's outputs (per-uid; length mismatches count as disagreement)."""
    total, hit = 0, 0
    for uid, o in ref.items():
        a, b = list(outs[uid].tokens), list(o.tokens)
        total += max(len(a), len(b))
        hit += sum(int(x == y) for x, y in zip(a, b))
    return hit / max(total, 1)


# ---------------------------------------------------------------------------
# Trained-model k_ratio sweep (+ int8 / hierarchical composition)
# ---------------------------------------------------------------------------


def quality_sweep() -> List[Row]:
    cfg, params, proj = get_trained_model()
    dcfg = data_config()
    # held-out copy-task batches: quality depends on long-range attention,
    # so the AQUA approximation level is visible in the ppl
    batches = [make_batch(dcfg, 90_000 + i) for i in range(4)]
    exact_cfg = dataclasses.replace(cfg, aqua=None)

    rows: List[Row] = []
    ppl0, acc0 = ppl_and_accuracy(exact_cfg, params, None, batches)
    rows.append(("quality/exact", 0.0, f"ppl={ppl0:.4f} acc={acc0:.4f}"))

    max_new = 16
    reqs = poisson_trace(8, mean_interarrival=2.0, prompt_lens=(8, 16, 24),
                         max_new_tokens=max_new, vocab_size=cfg.vocab_size,
                         seed=3)
    scfg = ServingConfig(max_lanes=4, max_seq=64, max_new_tokens=max_new)
    ref = ContinuousBatchingEngine(exact_cfg, params, None, serving=scfg,
                                   backend="dense-jnp").run(reqs)

    for k in (1.0, 0.75, 0.5):
        ck = dataclasses.replace(
            cfg, aqua=AquaConfig(k_ratio=k, block_dims=8))
        ppl, acc = ppl_and_accuracy(ck, params, proj, batches)
        eng = ContinuousBatchingEngine(ck, params, proj, serving=scfg,
                                       backend="aqua-masked-dense")
        m = match_fraction(eng.run(reqs), ref)
        rows.append((f"quality/aqua_k{k:g}", 0.0,
                     f"ppl={ppl:.4f} acc={acc:.4f} token_match={m:.3f}"))

    # composition rows: at the aggressive operating point the cache
    # quantization / page-granular token sparsity errors stack with the
    # dim-block truncation, so greedy agreement is measured for the
    # *composed* engine (ppl is a teacher-forced metric; the pool
    # mechanisms live in the serving engine, hence token_match only)
    c5 = dataclasses.replace(cfg, aqua=AquaConfig(k_ratio=0.5, block_dims=8))
    pscfg = dataclasses.replace(
        scfg, cache=CacheSpec(page_size=16, num_pages=14))
    eng = ContinuousBatchingEngine(
        c5, params, proj,
        serving=dataclasses.replace(pscfg, quant=QuantSpec(kv_dtype="int8")),
        backend="aqua-block-sparse")
    rows.append(("quality/aqua_k0.5+int8", 0.0,
                 f"token_match={match_fraction(eng.run(reqs), ref):.3f}"))
    eng = ContinuousBatchingEngine(
        c5, params, proj,
        serving=dataclasses.replace(
            pscfg,
            sparsity=SparsitySpec(page_keep_ratio=0.75, pin_recent_pages=2)),
        backend="aqua-block-sparse")
    rows.append(("quality/aqua_k0.5+hier", 0.0,
                 f"token_match={match_fraction(eng.run(reqs), ref):.3f}"))
    return rows


# ---------------------------------------------------------------------------
# HF-ingestion end-to-end quality (zero network)
# ---------------------------------------------------------------------------


def hf_ingest_quality() -> List[Row]:
    from repro.checkpoint.fixtures import write_hf_fixture
    from repro.checkpoint.hf import config_from_hf, load_hf_checkpoint
    from repro.core.calibration import calibrate
    from repro.data.pipeline import calibration_batches

    rows: List[Row] = []
    with tempfile.TemporaryDirectory() as td:
        outdir = os.path.join(td, "hf_ckpt")
        write_hf_fixture(outdir, variant="sharded")
        base = config_from_hf(outdir)
        params = load_hf_checkpoint(outdir, base)

        # offline SVD over real-text windows (byte-level ids fill the
        # fixture's 256-token vocab exactly)
        cap_model = build_model(base)

        def fwd_cap(p, batch):
            _, aux = cap_model.forward(p, batch, capture=True)
            return aux

        proj = calibrate(
            fwd_cap, params,
            calibration_batches(base, num_batches=2, batch=2, seq=48,
                                corpus_path=CALIBRATION_CORPUS), base)

        # held-out corpus windows (disjoint seed stream from calibration)
        hdcfg = DataConfig(vocab_size=base.vocab_size, seq_len=48,
                           global_batch=4, seed=77, kind="corpus",
                           corpus_path=CALIBRATION_CORPUS)
        held = [make_batch(hdcfg, i) for i in range(2)]

        max_new = 8
        reqs = poisson_trace(8, mean_interarrival=2.0,
                             prompt_lens=(8, 16, 24), max_new_tokens=max_new,
                             vocab_size=base.vocab_size, seed=5)
        scfg = ServingConfig(max_lanes=4, max_seq=64, max_new_tokens=max_new,
                             cache=CacheSpec(page_size=16, num_pages=12))
        multi = jax.device_count() >= 4
        if multi:
            from repro.launch.mesh import make_serving_mesh
            ref = ContinuousBatchingEngine(
                base, params, None, serving=scfg,
                backend="dense-jnp").run(reqs)

        for k in (1.0, 0.75, 0.5):
            ck = dataclasses.replace(
                base, aqua=AquaConfig(k_ratio=k, block_dims=8))
            ppl, _ = ppl_and_accuracy(ck, params, proj, held)
            rows.append((f"quality/hf_ppl_k{k:g}", 0.0, f"ppl={ppl:.4f}"))
            if not multi:
                rows.append((f"quality/hf_match_k{k:g}@mesh2x2", 0.0,
                             f"skipped=devices<4 ({jax.device_count()})"))
                continue
            # greedy agreement served through the production path: paged
            # pool on a 2x2 data×model mesh, kernel dispatch asserted so a
            # predicate regression can't silently measure the reference
            eng = ContinuousBatchingEngine(
                ck, params, proj, serving=scfg,
                backend="aqua-block-sparse",
                mesh=make_serving_mesh((2, 2)))
            plan = eng.dispatch_plan()
            assert plan.mesh_native and plan.paged, \
                f"hf_ingest mesh row left the kernel path: {plan}"
            m = match_fraction(eng.run(reqs), ref)
            assert eng.mesh_fallback_events() == (), \
                eng.mesh_fallback_events()
            rows.append((f"quality/hf_match_k{k:g}@mesh2x2", 0.0,
                         f"token_match={m:.3f}"))
    return rows
