"""Roofline analysis: turn dry-run JSONL records into the §Roofline table.

    PYTHONPATH=src python -m benchmarks.roofline results/roofline_single.jsonl

Per (arch × shape): the three roofline terms (compute / memory /
collective, seconds), the dominant bottleneck, MODEL_FLOPS (6·N·D dense /
6·N_active·D MoE), the useful-compute ratio MODEL_FLOPS / HLO_FLOPs, and a
one-line recommendation for the dominant term.
"""
from __future__ import annotations

import json
import sys

from repro.configs import SHAPES_BY_NAME, get_config
from repro.configs.base import ModelConfig, ShapeConfig


def active_params(cfg: ModelConfig) -> float:
    """Active (per-token) parameter count, embeddings excluded, unembed
    included as compute-bearing."""
    dm, L = cfg.d_model, cfg.num_layers
    n = 0.0
    a = cfg.attention
    if a is not None:
        attn = dm * a.head_dim * (a.num_heads + 2 * a.num_kv_heads) \
            + a.num_heads * a.head_dim * dm
        n_attn_layers = L
        if cfg.family == "hybrid":
            pat = cfg.rglru.block_pattern
            n_attn_layers = sum(1 for i in range(L)
                                if pat[i % len(pat)] == "attention")
        n += attn * n_attn_layers
    if cfg.family == "moe":
        m = cfg.moe
        n += L * (m.top_k + m.num_shared) * 3 * dm * m.expert_ff
        n += L * dm * m.num_experts  # router
    elif cfg.family == "ssm":
        s = cfg.ssm
        di = s.expand * dm
        proj_out = 2 * di + 2 * s.ngroups * s.state_dim + di // s.head_dim
        n += L * (dm * proj_out + di * dm)
    elif cfg.family == "hybrid":
        pat = cfg.rglru.block_pattern
        w = cfg.rglru.lru_width or dm
        n_rec = sum(1 for i in range(L)
                    if pat[i % len(pat)] == "recurrent")
        n += n_rec * (2 * dm * w + 2 * w * w + w * dm)
        n += L * 3 * dm * cfg.d_ff
    else:
        gate = 3 if cfg.act == "silu" else 2
        n += L * gate * dm * cfg.d_ff
        if cfg.family == "encdec":
            enc_attn = dm * cfg.attention.head_dim * 4 * cfg.attention.num_heads
            n += cfg.num_encoder_layers * (enc_attn + 2 * dm * cfg.d_ff)
            n += L * enc_attn  # decoder cross-attention
    n += dm * cfg.vocab_size  # unembed matmul
    return n


def model_flops(cfg: ModelConfig, shape: ShapeConfig, chips: int) -> float:
    """Useful model FLOPs per executed step, per chip."""
    n = active_params(cfg)
    b, s = shape.global_batch, shape.seq_len
    a = cfg.attention
    attn_ctx = 0.0
    if a is not None:
        ctx = s if a.window is None else min(s, a.window)
        per_tok = 4 * a.num_heads * a.head_dim * ctx  # scores + AV
        attn_ctx = per_tok * cfg.num_layers
    if shape.mode == "train":
        f = (6 * n + 3 * attn_ctx / 2) * b * s
    elif shape.mode == "prefill":
        f = (2 * n + attn_ctx / 2) * b * s   # causal: half the rectangle
    else:  # decode: one token per sequence
        f = (2 * n + attn_ctx) * b
    return f / chips


def render(path: str) -> str:
    rows = [json.loads(l) for l in open(path)]
    out = ["| arch | shape | t_compute | t_memory | t_collective | "
           "bottleneck | MODEL_FLOPS/chip | useful ratio | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped |"
                       f" — | — | {r['skipped'][:60]} |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | "
                       f"— | — | {r['error'][:60]} |")
            continue
        cfg = get_config(r["arch"])
        shape = SHAPES_BY_NAME[r["shape"]]
        mf = model_flops(cfg, shape, r["chips"])
        ratio = mf / r["hlo_flops"] if r["hlo_flops"] else float("nan")
        note = {
            "compute": "MXU-bound: increase arithmetic intensity "
                       "(larger tiles/batch)",
            "memory": "HBM-bound: cut activation/score traffic (fusion, "
                      "bf16 scores, AQUA k_ratio, Pallas decode kernel)",
            "collective": "ICI-bound: overlap TP collectives / "
                          "reduce-scatter instead of all-reduce",
        }[r["bottleneck"]]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['bottleneck']} | {mf:.3e} | {ratio:.2f} | {note} |")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else \
        "benchmarks/results/roofline_single.jsonl"
    print(render(path))


if __name__ == "__main__":
    main()
