"""Paper-table fidelity benchmarks (one function per table/figure).

Each returns a list of CSV rows (name, us_per_call, derived). The derived
column carries the paper-metric (NLL, L_info, byte ratio, ...) so the CSV
doubles as the reproduction record in EXPERIMENTS.md §Fidelity.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (data_config, eval_nll, get_trained_model,
                               timeit, BENCH_SEQ)
from repro.configs.base import AquaConfig, CacheSpec, QuantSpec
from repro.core import aqua as aqua_lib
from repro.data.pipeline import make_batch
from repro.models import build_model

Row = Tuple[str, float, str]


# ---------------------------------------------------------------------------
# Figure 2: information-retention loss — offline vs online projection,
# magnitude vs naive slicing.
# ---------------------------------------------------------------------------


def fig2_info_retention() -> List[Row]:
    cfg, params, proj = get_trained_model()
    model = build_model(cfg)
    batch = make_batch(data_config(), 70_000)
    _, aux = model.forward(params, {"tokens": batch["tokens"]}, capture=True)
    q, k = aux["qk"][0]              # layer 0: (B,S,KV,G,D), (B,S,KV,D)
    d = q.shape[-1]
    kvh = k.shape[2]
    # head 0 group (paper: layer 0 head 0 of the GQA group)
    qs = q[:, :, 0].reshape(-1, d)   # all group queries
    ks = k[:, :, 0].reshape(-1, d)
    vecs = jnp.concatenate([qs, ks], 0)

    p_off = proj.p[0, 0]                                  # offline calibrated
    p_on = aqua_lib.compute_projection(vecs)              # online "same data"

    rows: List[Row] = []
    for frac in (0.25, 0.5, 0.75):
        kd = int(d * frac)
        for pname, p in (("offline", p_off), ("online", p_on)):
            vh = vecs @ p
            m_mag = aqua_lib.magnitude_mask(vh, kd)
            m_sl = aqua_lib.slicing_mask(d, kd, vh)
            l_mag = float(aqua_lib.info_retention_loss(vecs, vh, m_mag).mean())
            l_sl = float(aqua_lib.info_retention_loss(vecs, vh, m_sl).mean())
            rows.append((f"fig2/{pname}_magnitude_k{frac}", 0.0,
                         f"L_info={l_mag:.4f}"))
            rows.append((f"fig2/{pname}_slicing_k{frac}", 0.0,
                         f"L_info={l_sl:.4f}"))
    # headline checks: offline≈online; magnitude < slicing
    vh_off = vecs @ p_off
    vh_on = vecs @ p_on
    kd = d // 2
    lo = float(aqua_lib.info_retention_loss(
        vecs, vh_off, aqua_lib.magnitude_mask(vh_off, kd)).mean())
    ln = float(aqua_lib.info_retention_loss(
        vecs, vh_on, aqua_lib.magnitude_mask(vh_on, kd)).mean())
    ls = float(aqua_lib.info_retention_loss(
        vecs, vh_off, aqua_lib.slicing_mask(d, kd, vh_off)).mean())
    rows.append(("fig2/offline_vs_online_gap", 0.0,
                 f"gap={abs(lo-ln):.4f}"))
    rows.append(("fig2/slicing_over_magnitude", 0.0,
                 f"ratio={ls/max(lo,1e-9):.2f}x"))
    return rows


# ---------------------------------------------------------------------------
# Table 1 / 4: standalone AQUA — quality vs k_ratio.
# ---------------------------------------------------------------------------


def table1_standalone() -> List[Row]:
    cfg, params, proj = get_trained_model()
    rows: List[Row] = []
    base = eval_nll(cfg, params, None)
    rows.append(("table1/baseline", _fwd_time(cfg, params, None),
                 f"nll={base:.4f}"))
    for kr in (0.9, 0.75, 0.5, 0.3):
        c = dataclasses.replace(cfg, aqua=AquaConfig(k_ratio=kr,
                                                     block_dims=1))
        nll = eval_nll(c, params, proj)
        rows.append((f"table1/k{kr}", _fwd_time(c, params, proj),
                     f"nll={nll:.4f} delta={nll-base:+.4f}"))
    return rows


def _fwd_time(cfg, params, proj) -> float:
    from repro.models.layers import cross_entropy
    model = build_model(cfg)
    p_arr = None if proj is None else proj.p
    batch = make_batch(data_config(), 80_000)
    fn = jax.jit(lambda pr, b: cross_entropy(
        model.forward(pr, b, aqua_proj=p_arr), b["labels"]))
    return timeit(fn, params, batch)


# ---------------------------------------------------------------------------
# Table 2: AQUA-H2O synergy.
# ---------------------------------------------------------------------------


def table2_aqua_h2o() -> List[Row]:
    cfg, params, proj = get_trained_model()
    model = build_model(cfg)
    dcfg = data_config()
    rows: List[Row] = []
    for h2o in (1.0, 0.75, 0.5):
        for kr in (1.0, 0.75, 0.5):
            c = dataclasses.replace(
                cfg, aqua=AquaConfig(k_ratio=kr, h2o_ratio=h2o,
                                     block_dims=1))
            nll = _decode_nll(c, params, proj, dcfg)
            rows.append((f"table2/h2o{h2o}_k{kr}", 0.0, f"nll={nll:.4f}"))
    return rows


def _decode_nll(cfg, params, proj, dcfg, prompt_len=None) -> float:
    """Teacher-forced decode NLL through the *cache* path (exercises the
    eviction policy, unlike forward()). Scores only the attention-dependent
    copy region (the second half)."""
    model = build_model(cfg)
    p_arr = None if proj is None else proj.p
    batch = make_batch(dcfg, 90_000)
    toks = batch["tokens"][:4]
    s = toks.shape[1]
    if prompt_len is None:
        prompt_len = (s + 1) // 2 + 1   # prompt = the full prefix
    logits, state = jax.jit(
        lambda pr, t: model.prefill(pr, {"tokens": t}, BENCH_SEQ,
                                    aqua_proj=p_arr)
    )(params, toks[:, :prompt_len])
    step = jax.jit(lambda pr, st, t: model.decode_step(pr, st, t,
                                                       aqua_proj=p_arr))
    nll = []
    for t in range(prompt_len, s):
        logp = jax.nn.log_softmax(logits, -1)
        nll.append(-np.asarray(
            jnp.take_along_axis(logp, toks[:, t][:, None], -1)).mean())
        logits, state = step(params, state, toks[:, t])
    return float(np.mean(nll))


# ---------------------------------------------------------------------------
# Table 3: AQUA-Memory — KV-cache bytes vs quality.
# ---------------------------------------------------------------------------


def table3_aqua_memory() -> List[Row]:
    cfg, params, proj = get_trained_model()
    from repro.serving import ServeEngine
    rows: List[Row] = []
    base_bytes = ServeEngine(cfg, params, None, max_seq=BENCH_SEQ
                             ).cache_bytes(4)
    base = eval_nll(cfg, params, None)
    rows.append(("table3/full_attn", 0.0,
                 f"nll={base:.4f} cache_bytes=1.00x"))
    for sr in (0.1, 0.25):
        for kr in (1.0, 0.9, 0.75):
            c = dataclasses.replace(
                cfg, aqua=AquaConfig(k_ratio=kr, s_ratio=sr, block_dims=1))
            eng = ServeEngine(c, params, proj, max_seq=BENCH_SEQ)
            nll = eval_nll(c, params, proj)
            ratio = eng.cache_bytes(4) / base_bytes
            e_ratio = c.aqua.e_ratio
            rows.append((f"table3/s{sr}_k{kr}", 0.0,
                         f"nll={nll:.4f} cache_bytes={ratio:.2f}x "
                         f"E_ratio={e_ratio:.3f}"))
    return rows


# ---------------------------------------------------------------------------
# Corollary A.3: computational break-even point.
# ---------------------------------------------------------------------------


def breakeven() -> List[Row]:
    """Corollary A.3. The paper states the bound with the projection cost
    as O(d²) (both q and k projections folded into the constant); exact
    multiply counting gives threshold 2d²/(d−k). We report the paper's
    big-O form and verify the exact count on both sides of the exact
    threshold."""
    rows: List[Row] = []
    d = 128
    for k in (16, 64, 112):
        paper_theory = d * d / (d - k)
        exact = 2 * d * d / (d - k)
        rows.append((f"breakeven/d128_k{k}", 0.0,
                     f"paper_O_tokens={paper_theory:.0f} "
                     f"exact_tokens={exact:.0f}"))
        for seq in (int(exact * 0.5), int(exact * 2)):
            c_std = seq * d
            c_aqua = 2 * d * d + seq * k   # q,k projections + sparse dot
            faster = c_aqua < c_std
            expect = seq > exact
            assert faster == expect, (k, seq)
            rows.append((f"breakeven/d128_k{k}_seq{seq}", 0.0,
                         f"aqua_faster={faster}"))
    # with folded projections (DESIGN.md §2) the overhead term vanishes:
    rows.append(("breakeven/folded_projection", 0.0,
                 "breakeven_tokens=0 (projection folded into W_Q/W_K)"))
    return rows


# ---------------------------------------------------------------------------
# TPU-adaptation ablation: selection granularity (block_dims 1 vs 8).
# ---------------------------------------------------------------------------


def block_granularity() -> List[Row]:
    cfg, params, proj = get_trained_model()
    rows: List[Row] = []
    for bd in (1, 8):
        c = dataclasses.replace(cfg, aqua=AquaConfig(k_ratio=0.75,
                                                     block_dims=bd))
        nll = eval_nll(c, params, proj)
        rows.append((f"block_granularity/bd{bd}", 0.0, f"nll={nll:.4f}"))
    # L_info at both granularities on real activations
    model = build_model(cfg)
    batch = make_batch(data_config(), 70_001)
    _, aux = model.forward(params, {"tokens": batch["tokens"]}, capture=True)
    q, _ = aux["qk"][0]
    d = q.shape[-1]
    qs = (q[:, :, 0].reshape(-1, d)) @ proj.p[0, 0]
    kd = int(d * 0.75) // 8 * 8
    for bd in (1, 8):
        m = aqua_lib.magnitude_mask(qs, kd, block_dims=bd)
        l = float(aqua_lib.info_retention_loss(qs, qs, m).mean())
        rows.append((f"block_granularity/Linfo_bd{bd}", 0.0,
                     f"L_info={l:.4f}"))
    return rows


# ---------------------------------------------------------------------------
# Kernel-level: prefill backend equivalence + timing (no trained model; fast
# enough for the CI smoke).
# ---------------------------------------------------------------------------


def prefill_backends() -> List[Row]:
    from repro.kernels.ops import (aqua_prefill, block_counts,
                                  flash_attention, round_k_dims)
    from repro.kernels.ref import aqua_prefill_ref, flash_attention_ref
    from repro.core.aqua import chunk_topk_block_indices
    b, h, kvh, s, d = 1, 4, 2, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, kvh, s, d))
    v = jax.random.normal(ks[2], (b, kvh, s, d))
    lengths = jnp.full((b,), s, jnp.int32)
    rows: List[Row] = []

    us = timeit(lambda: flash_attention(q, k, v, causal=True,
                                        interpret=True), iters=3)
    err = float(jnp.max(jnp.abs(
        flash_attention(q, k, v, causal=True, interpret=True)
        - flash_attention_ref(q, k, v, causal=True))))
    rows.append(("prefill/flash_vs_dense", us, f"max_abs_err={err:.2e}"))

    for kr in (0.5, 0.75, 1.0):
        fn = lambda: aqua_prefill(q, k, v, lengths, k_ratio=kr,  # noqa: E731
                                  block_dims=8, q_blk=32, k_blk=32,
                                  interpret=True)
        us = timeit(fn, iters=3)
        k_dims = round_k_dims(d, kr, 8)
        bi = chunk_topk_block_indices(q, k_dims, 8, 32, lengths)
        ref = aqua_prefill_ref(q, k, v, bi, lengths, 8, 32)
        err = float(jnp.max(jnp.abs(fn() - ref)))
        # score-read HBM traffic of the kernel relative to dense flash
        nb, nb_sel = block_counts(d, kr, 8)
        ratio = nb_sel / nb
        rows.append((f"prefill/aqua_block_sparse_k{kr}", us,
                     f"max_abs_err={err:.2e} score_bytes_ratio={ratio:.3f}"))

    # kernel under a 2x2 serving mesh: the same Pallas prefill wrapped in
    # shard_map (batch over `data`, KV heads + their query groups over
    # `model`, per-shard block-index tables). Per-(row, head) work is
    # independent, so the wrap must be bit-identical to the single-device
    # kernel; max_abs_err gates that. Skipped (loudly) below 4 devices —
    # CI runs under XLA_FLAGS=--xla_force_host_platform_device_count=8.
    if jax.device_count() >= 4:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh((2, 2))
        qb = jnp.concatenate([q, q * 0.5], axis=0)      # batch of 2
        kb = jnp.concatenate([k, k * 0.5], axis=0)
        vb = jnp.concatenate([v, v], axis=0)
        lb = jnp.full((2,), s, jnp.int32)

        def core(qs, ks_, vs, ls):
            return aqua_prefill(qs, ks_, vs, ls, k_ratio=0.5, block_dims=8,
                                q_blk=32, k_blk=32, interpret=True)

        meshed = jax.jit(shard_map(
            core, mesh=mesh,
            in_specs=(P("data", "model", None, None),
                      P("data", "model", None, None),
                      P("data", "model", None, None), P("data")),
            out_specs=P("data", "model", None, None), check_rep=False))
        us = timeit(lambda: meshed(qb, kb, vb, lb), iters=3)
        err = float(jnp.max(jnp.abs(meshed(qb, kb, vb, lb)
                                    - core(qb, kb, vb, lb))))
        nb, nb_sel = block_counts(d, 0.5, 8)
        rows.append(("prefill/aqua_block_sparse@mesh2x2", us,
                     f"max_abs_err={err:.2e} "
                     f"score_bytes_ratio={nb_sel / nb:.3f}"))
    else:
        rows.append(("prefill/aqua_block_sparse@mesh2x2", 0.0,
                     f"skipped=devices<4 ({jax.device_count()})"))
    return rows


# ---------------------------------------------------------------------------
# Kernel-level: HBM bytes of the block-sparse decode vs dense decode.
# ---------------------------------------------------------------------------


def kernel_bandwidth() -> List[Row]:
    from repro.kernels.ops import aqua_decode, block_counts
    from repro.kernels.ref import aqua_decode_ref
    from repro.core.aqua import topk_block_indices
    b, h, kvh, s, d = 1, 4, 2, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, d))
    khat = jax.random.normal(ks[1], (b, kvh, s, d))
    v = jax.random.normal(ks[2], (b, kvh, s, d))
    lengths = jnp.full((b,), s, jnp.int32)
    rows: List[Row] = []
    dense_bytes = khat.size * 2 + v.size * 2          # bf16 stream of K + V
    for kr in (0.5, 0.75, 1.0):
        us = timeit(lambda: aqua_decode(q, khat, v, lengths, k_ratio=kr),
                    iters=3)
        nb, nb_sel = block_counts(d, kr, 8)
        kernel_bytes = (khat.size * 2) * (nb_sel / nb) + v.size * 2
        rows.append((f"kernel/aqua_decode_k{kr}", us,
                     f"hbm_bytes_ratio={kernel_bytes/dense_bytes:.3f}"))
    us_ref = timeit(lambda: aqua_decode_ref(
        q, khat, v, topk_block_indices(q, 48, 8), lengths, 8), iters=3)
    rows.append(("kernel/dense_ref", us_ref, "hbm_bytes_ratio=1.000"))

    # paged decode: the same cache content scattered into a *permuted*
    # page pool — the scalar-prefetched page table restores logical order
    # inside the kernel's index_map, so the output must match the
    # contiguous kernel and the HBM score-read ratio is unchanged (pages
    # only redirect addressing; the pool itself is what shrinks, which
    # the serving rows report as cache bytes / pool_util)
    from repro.kernels.ops import aqua_paged_decode
    ps = 128
    npg = s // ps
    perm = np.arange(npg, dtype=np.int32)[::-1].copy()   # reversed layout
    pages_k = khat[0].reshape(kvh, npg, ps, d).transpose(1, 0, 2, 3)
    pages_v = v[0].reshape(kvh, npg, ps, d).transpose(1, 0, 2, 3)
    pool_k = jnp.zeros_like(pages_k).at[perm].set(pages_k)
    pool_v = jnp.zeros_like(pages_v).at[perm].set(pages_v)
    table = jnp.asarray(perm)[None]                      # (1, npg)
    for kr in (0.5, 0.75):
        us = timeit(lambda: aqua_paged_decode(
            q, pool_k, pool_v, table, lengths, k_ratio=kr, block_dims=8,
            seq_blk=ps), iters=3)
        err = float(jnp.max(jnp.abs(
            aqua_paged_decode(q, pool_k, pool_v, table, lengths,
                              k_ratio=kr, block_dims=8, seq_blk=ps)
            - aqua_decode(q, khat, v, lengths, k_ratio=kr))))
        nb, nb_sel = block_counts(d, kr, 8)
        kernel_bytes = (khat.size * 2) * (nb_sel / nb) + v.size * 2
        rows.append((f"kernel/aqua_paged_decode_k{kr}", us,
                     f"max_abs_err={err:.2e} "
                     f"hbm_bytes_ratio={kernel_bytes / dense_bytes:.3f}"))
    return rows


# ---------------------------------------------------------------------------
# Quantized KV pools: int8 fidelity gate (no trained model; CI smoke).
# ---------------------------------------------------------------------------


def quant_fidelity() -> List[Row]:
    """int8 page-pool fidelity, kernel- and serving-level.

    Kernel level: quantize a permuted page pool at both scale
    granularities and decode through the scale-folded Pallas path; the
    max_abs_err rows compare against the SAME kernel over the
    dequantized full-precision pools, so addressing/selection cancels
    and only the scale-folding arithmetic is judged (must be ~float
    rounding). The roundtrip rows carry the quantization noise itself
    (~amax/254 per page).

    Serving level: greedy-token identity of an int8 paged engine vs the
    full-precision paged engine on the same trace, swept across
    k_ratio × quant mode — the tolerance record for how often int8
    rounding flips an argmax. Gated by benchmarks/compare.py
    (token_match must not drift below the committed baseline).
    """
    from repro.configs import reduced
    from repro.configs.base import ServingConfig
    from repro.core.calibration import identity_projections
    from repro.kernels.ops import aqua_paged_decode
    from repro.serving import ContinuousBatchingEngine, poisson_trace

    rows: List[Row] = []
    b, kvh, s, d = 1, 2, 256, 64
    ks_ = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks_[0], (b, 4, d))
    khat = jax.random.normal(ks_[1], (b, kvh, s, d))
    v = jax.random.normal(ks_[2], (b, kvh, s, d))
    lengths = jnp.full((b,), s, jnp.int32)
    ps = 128
    npg = s // ps
    perm = np.arange(npg, dtype=np.int32)[::-1].copy()
    pages_k = khat[0].reshape(kvh, npg, ps, d).transpose(1, 0, 2, 3)
    pages_v = v[0].reshape(kvh, npg, ps, d).transpose(1, 0, 2, 3)
    table = jnp.asarray(perm)[None]

    def quantize(pages, gran):
        red = (2, 3) if gran == "page_head" else (1, 2, 3)
        scale = (jnp.max(jnp.abs(pages), axis=red) / 127.0
                 ).astype(jnp.float32)
        if gran == "page":
            scale = scale[:, None]                       # (P, 1)
        safe = jnp.where(scale > 0, scale, 1.0)
        ints = jnp.clip(jnp.round(pages / safe[..., None, None]),
                        -127, 127)
        return ints.astype(jnp.int8), scale

    for gran in ("page_head", "page"):
        qk, sk = quantize(pages_k, gran)
        qv, sv = quantize(pages_v, gran)
        scatter = lambda x: jnp.zeros_like(x).at[perm].set(x)  # noqa: E731
        qk_pool, qv_pool = scatter(qk), scatter(qv)
        sk_pool, sv_pool = scatter(sk), scatter(sv)
        deq_k = qk_pool.astype(jnp.float32) * sk_pool[..., None, None]
        deq_v = qv_pool.astype(jnp.float32) * sv_pool[..., None, None]
        rt = float(jnp.max(jnp.abs(scatter(pages_k) - deq_k)))
        rows.append((f"quant/int8_roundtrip_{gran}", 0.0,
                     f"max_abs_err={rt:.2e}"))
        for kr in (0.5, 0.75, 1.0):
            out_q = aqua_paged_decode(q, qk_pool, qv_pool, table, lengths,
                                      k_scale=sk_pool, v_scale=sv_pool,
                                      k_ratio=kr, block_dims=8, seq_blk=ps)
            out_f = aqua_paged_decode(q, deq_k, deq_v, table, lengths,
                                      k_ratio=kr, block_dims=8, seq_blk=ps)
            err = float(jnp.max(jnp.abs(out_q - out_f)))
            assert err < 1e-4, \
                f"scale-folded kernel diverged from dequantized pools: " \
                f"{err} (k_ratio={kr}, {gran})"
            rows.append((f"quant/int8_paged_decode_k{kr}_{gran}", 0.0,
                         f"max_abs_err={err:.2e}"))

    # greedy-token-identity sweep (k_ratio × quant mode)
    cfg = dataclasses.replace(reduced("qwen3-0.6b"), remat=False,
                              dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    ident = identity_projections(cfg.num_layers, cfg.attention.num_kv_heads,
                                 cfg.attention.head_dim)
    reqs = poisson_trace(8, mean_interarrival=2.0, prompt_lens=(8, 14),
                         max_new_tokens=12, vocab_size=cfg.vocab_size,
                         seed=0)
    scfg = ServingConfig(max_lanes=4, max_seq=64, max_new_tokens=12,
                         prompt_bucket=8,
                         cache=CacheSpec(page_size=16, num_pages=16))
    modes = (("int8", QuantSpec(kv_dtype="int8")),
             ("int8-mixed", QuantSpec(kv_dtype="int8",
                                      hot_resident_fraction=0.25)))
    for kr in (0.5, 0.75):
        c = dataclasses.replace(cfg, aqua=AquaConfig(k_ratio=kr,
                                                     block_dims=8))
        ref = ContinuousBatchingEngine(
            c, params, ident, serving=scfg,
            backend="aqua-block-sparse").run(reqs)
        for mode, quant in modes:
            eng = ContinuousBatchingEngine(
                c, params, ident,
                serving=dataclasses.replace(scfg, quant=quant),
                backend="aqua-block-sparse")
            out = eng.run(reqs)
            total = match = 0
            for uid, o in ref.items():
                want, got = list(o.tokens), list(out[uid].tokens)
                total += len(want)
                match += sum(a == b_ for a, b_ in zip(want, got))
            frac = match / total
            rows.append((f"quant/greedy_identity_k{kr}_{mode}", 0.0,
                         f"token_match={frac:.3f}"))
    return rows


# ---------------------------------------------------------------------------
# Serving: continuous-batching throughput + lane occupancy on a Poisson
# mixed-traffic trace (no trained model; CI smoke). The rectangular-engine
# row is the contrast: it serves the same trace one fixed batch at a time,
# so requests never overlap (occupancy ~1 request-batch, arrival gaps idle).
# ---------------------------------------------------------------------------


def serving_throughput() -> List[Row]:
    import time

    from repro.configs import reduced
    from repro.configs.base import ServingConfig
    from repro.core.calibration import identity_projections
    from repro.serving import ContinuousBatchingEngine, ServeEngine, \
        poisson_trace

    cfg = dataclasses.replace(reduced("qwen3-0.6b"), remat=False,
                              dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    ident = identity_projections(cfg.num_layers, cfg.attention.num_kv_heads,
                                 cfg.attention.head_dim)
    # long enough that one drive is an O(100ms+) measurement — the
    # regression gate keys off these numbers, and best-of-N over a
    # too-short drive still inherits CI-machine scheduling jitter
    max_new = 24
    reqs = poisson_trace(16, mean_interarrival=2.0, prompt_lens=(8, 14, 20),
                         max_new_tokens=max_new, vocab_size=cfg.vocab_size,
                         seed=0)
    scfg = ServingConfig(max_lanes=4, max_seq=64, max_new_tokens=max_new,
                         prompt_bucket=8)

    def timed_drive(eng, repeats: int = 5, trace=None):
        """Warm up (compile admit+step), then best-of-N timed drives —
        the bench-regression gate compares these numbers across CI runs,
        so a single noisy wall-clock sample is not acceptable."""
        trace = reqs if trace is None else trace
        for o in eng.run(trace).values():
            assert o.tokens, o
        best = float("inf")
        for _ in range(repeats):
            t0 = time.time()
            outs = eng.run(trace)
            best = min(best, time.time() - t0)
            assert all(len(o.tokens) == max_new for o in outs.values())
        return best, eng.stats

    rows: List[Row] = []
    for backend in ("dense-jnp", "aqua-masked-dense"):
        aqua = None if backend == "dense-jnp" else AquaConfig(k_ratio=0.75,
                                                              block_dims=1)
        c = dataclasses.replace(cfg, aqua=aqua)
        eng = ContinuousBatchingEngine(c, params, ident if aqua else None,
                                       serving=scfg, backend=backend)
        dt, st = timed_drive(eng)
        rows.append((f"serving/{backend}", dt / max(st.decode_steps, 1) * 1e6,
                     f"tok_s={st.tokens_emitted / dt:.1f} "
                     f"occupancy={st.mean_occupancy:.2f}"))

    # block-paged KV cache rows: the pool (12 pages of 16 tokens) is 25%
    # smaller than lane-stripe parity (4 lanes × 4 pages) — admissions
    # queue on free pages instead of OOMing, and cache_bytes drops by the
    # same ratio. pool_util (mean fraction of pool pages in use) and
    # prefill_saved (prompt tokens never re-prefilled thanks to prefix
    # sharing) are gated by benchmarks/compare.py: a paging regression
    # (page leak, sharing broken) moves them and fails the bench job.
    pscfg = dataclasses.replace(scfg,
                                cache=CacheSpec(page_size=16, num_pages=12))

    def paged_row(name, eng, reqs_override=None):
        dt, st = timed_drive(eng, trace=reqs_override)
        pool = eng.page_pool
        rows.append((f"serving/{name}", dt / max(st.decode_steps, 1) * 1e6,
                     f"tok_s={st.tokens_emitted / dt:.1f} "
                     f"occupancy={st.mean_occupancy:.2f} "
                     f"pool_util={pool.mean_utilization:.3f} "
                     f"prefill_saved={pool.tokens_saved}"))

    paged_row("paged-dense-jnp",
              ContinuousBatchingEngine(cfg, params, None, serving=pscfg,
                                       backend="dense-jnp"))
    aqua8 = AquaConfig(k_ratio=0.5, block_dims=8)
    paged_row("paged-aqua-block-sparse",
              ContinuousBatchingEngine(
                  dataclasses.replace(cfg, aqua=aqua8), params, ident,
                  serving=pscfg, backend="aqua-block-sparse"))
    # int8-quantized page pools: same trace/geometry as the fp row above,
    # so the pool_util/throughput trajectory isolates the quantization
    # overhead (requant-on-growth inserts) while cache bytes drop ~4x
    qscfg = dataclasses.replace(pscfg, quant=QuantSpec(kv_dtype="int8"))
    paged_row("paged-aqua-int8",
              ContinuousBatchingEngine(
                  dataclasses.replace(cfg, aqua=aqua8), params, ident,
                  serving=qscfg, backend="aqua-block-sparse"))
    # prefix-shared trace: every prompt opens with the same 16-token
    # (page-aligned) prefix, so all admissions after the first skip its
    # prefill and map the sharer's pages read-only
    pre_rng = np.random.default_rng(7)
    prefix = pre_rng.integers(0, cfg.vocab_size, size=(16,), dtype=np.int32)
    shared_reqs = [
        dataclasses.replace(
            r, tokens=np.concatenate([prefix, np.asarray(r.tokens)]))
        for r in poisson_trace(12, mean_interarrival=2.0,
                               prompt_lens=(8, 14, 20),
                               max_new_tokens=max_new,
                               vocab_size=cfg.vocab_size, seed=0)
    ]
    paged_row("paged-prefix-shared",
              ContinuousBatchingEngine(cfg, params, None, serving=pscfg,
                                       backend="dense-jnp"),
              reqs_override=shared_reqs)

    # chunked-prefill/decode interleaving: the same mixed trace with long
    # prompts served twice. Monolithic admission stalls every decoding
    # lane for a whole co-tenant prefill; a 16-token budget bounds the
    # stall at one chunk step. p99 inter-token latency and the SLO-miss
    # rate are this pair's contract — benchmarks/compare.py checks the
    # chunked row beats monolithic *within the same dump* (machine speed
    # cancels) at equal normalized throughput.
    mixed_reqs = poisson_trace(12, mean_interarrival=4.0,
                               prompt_lens=(8, 48, 96),
                               max_new_tokens=max_new,
                               vocab_size=cfg.vocab_size, seed=1)
    mscfg = dataclasses.replace(scfg, max_seq=160)
    slo_s = 0.025
    for label, budget in (("interleave-monolithic", None),
                          ("interleave-chunked", 16)):
        eng = ContinuousBatchingEngine(
            cfg, params, None,
            serving=dataclasses.replace(mscfg, prefill_budget_tokens=budget),
            backend="dense-jnp")
        if budget is not None:
            assert eng.dispatch_plan().chunked_prefill, \
                f"interleave bench row fell back to monolithic admission: " \
                f"{eng.dispatch_plan().chunked_reasons}"
        dt, st = timed_drive(eng, trace=mixed_reqs)
        rows.append((f"serving/{label}",
                     dt / max(st.decode_steps, 1) * 1e6,
                     f"tok_s={st.tokens_emitted / dt:.1f} "
                     f"occupancy={st.mean_occupancy:.2f} "
                     f"p50_itl_ms={st.itl_percentile(50) * 1e3:.2f} "
                     f"p99_itl_ms={st.itl_percentile(99) * 1e3:.2f} "
                     f"slo_miss={st.slo_miss_rate(slo_s):.3f}"))

    # mesh-native serving (2×2 data×model) — the sharded row of the bench
    # trajectory. Skipped (not silently: a sentinel row records why) when
    # the platform has fewer than 4 devices; CI's bench-regression gate
    # runs under XLA_FLAGS=--xla_force_host_platform_device_count=8.
    if jax.device_count() >= 4:
        from repro.launch.mesh import make_serving_mesh
        eng = ContinuousBatchingEngine(cfg, params, None, serving=scfg,
                                       backend="dense-jnp",
                                       mesh=make_serving_mesh((2, 2)))
        dt, st = timed_drive(eng)
        rows.append(("serving/dense-jnp@mesh2x2",
                     dt / max(st.decode_steps, 1) * 1e6,
                     f"tok_s={st.tokens_emitted / dt:.1f} "
                     f"occupancy={st.mean_occupancy:.2f}"))

        # mesh kernel rows: the shard_mapped AQUA block-sparse Pallas
        # path vs the masked-dense reference under the *same* 2x2 mesh —
        # the trajectory the mesh-native kernel dispatch is meant to
        # protect. block_dims=8 so the kernels actually engage; same
        # best-of-5 as every other gated serving row (the 20% threshold's
        # noise analysis in benchmarks/compare.py assumes it).
        c8 = dataclasses.replace(cfg, aqua=aqua8)
        for backend in ("aqua-block-sparse", "aqua-masked-dense"):
            eng = ContinuousBatchingEngine(c8, params, ident, serving=scfg,
                                           backend=backend,
                                           mesh=make_serving_mesh((2, 2)))
            if backend == "aqua-block-sparse":
                # keep the row's label honest: fail the bench loudly if a
                # dispatch regression would silently measure the fallback
                # under the kernel's name
                assert eng.dispatch_plan().mesh_native, \
                    "block-sparse engine did not plan the shard_mapped " \
                    "kernel path for the mesh2x2 bench row"
            dt, st = timed_drive(eng)
            rows.append((f"serving/{backend}@mesh2x2",
                         dt / max(st.decode_steps, 1) * 1e6,
                         f"tok_s={st.tokens_emitted / dt:.1f} "
                         f"occupancy={st.mean_occupancy:.2f}"))

        # paged pool + mesh: the production configuration — the paged
        # kernel runs shard_mapped (lane-partitioned page tables,
        # lane-global KV-sharded pool), so the k_ratio savings and the
        # pool's HBM savings finally stack. The plan assertion keeps this
        # row on the kernel path forever.
        eng = ContinuousBatchingEngine(c8, params, ident, serving=pscfg,
                                       backend="aqua-block-sparse",
                                       mesh=make_serving_mesh((2, 2)))
        plan = eng.dispatch_plan()
        assert plan.mesh_native and plan.paged, \
            f"paged mesh2x2 bench row left the kernel path: {plan}"
        paged_row("paged-aqua-block-sparse@mesh2x2", eng)
        assert eng.mesh_fallback_events() == (), eng.mesh_fallback_events()

        # int8 pools on the mesh: scale metadata shards with the pages
        # over `model` and the scale-folded kernel path must stay
        # shard_mapped (quantization is folded into the kernel's softmax
        # scale, not a reason to fall back) — the plan assertion plus the
        # zero-fallback check keep this row on the kernel path forever.
        eng = ContinuousBatchingEngine(c8, params, ident, serving=qscfg,
                                       backend="aqua-block-sparse",
                                       mesh=make_serving_mesh((2, 2)))
        plan = eng.dispatch_plan()
        assert plan.mesh_native and plan.paged \
            and plan.quantization == "int8", \
            f"int8 paged mesh2x2 bench row left the kernel path: {plan}"
        paged_row("paged-aqua-int8@mesh2x2", eng)
        assert eng.mesh_fallback_events() == (), eng.mesh_fallback_events()
    else:
        rows.append(("serving/dense-jnp@mesh2x2", 0.0,
                     f"skipped=devices<4 ({jax.device_count()})"))
        for backend in ("aqua-block-sparse", "aqua-masked-dense"):
            rows.append((f"serving/{backend}@mesh2x2", 0.0,
                         f"skipped=devices<4 ({jax.device_count()})"))
        for name in ("paged-aqua-block-sparse@mesh2x2",
                     "paged-aqua-int8@mesh2x2"):
            rows.append((f"serving/{name}", 0.0,
                         f"skipped=devices<4 ({jax.device_count()})"))

    # rectangular contrast: one fixed batch per arrival "wave" — requests
    # cannot overlap across waves, so per-wave occupancy is 1 wave at a
    # time. Also the machine-speed anchor the regression gate normalizes
    # serving tok/s against, so it gets the same warm-up + best-of-N.
    eng = ServeEngine(cfg, params, None, max_seq=64)

    def rect_drive():
        t0 = time.time()
        toks = 0
        for r in reqs:                   # serialized: no cross-request overlap
            res = eng.generate(
                {"tokens": jnp.asarray(np.asarray(r.tokens)[None])},
                steps=max_new)
            toks += res.tokens.shape[1]
        return time.time() - t0, toks
    rect_drive()                         # warm-up: compile per prompt length
    dt, toks = min(rect_drive() for _ in range(5))
    rows.append(("serving/rectangular_serialized", 0.0,
                 f"tok_s={toks / dt:.1f} occupancy=1.00"))
    return rows


# ---------------------------------------------------------------------------
# Hierarchical AQUA at long context: 32k/64k byte accounting, executed
# kernel fidelity at a reduced long geometry, and the serving-level
# greedy-identity record (no trained model; CI smoke).
# ---------------------------------------------------------------------------


def longcontext_bench() -> List[Row]:
    """Long-context hierarchical (page x dim-block) decode/prefill family.

    Byte rows are *structural*: decode HBM traffic per token per lane at
    32k/64k follows directly from the tile sets the kernels stream
    (dim-block counts, participating pages), so the rows are exact and
    machine-independent -- a CPU CI judges the same numbers a TPU would.
    ``hbm_bytes_ratio`` is gated against the committed baseline by
    benchmarks/compare.py; the hierarchical rows additionally carry
    ``keep_ratio``/``bytes_per_tok`` for the within-dump contract
    (hierarchical bytes <= keep_ratio x paged, monotone in the ratio).

    The executed rows run the real hierarchical Pallas decode kernel at a
    reduced long geometry (2048 tokens, 16 pages): the participating-page
    subset is compared against the contiguous kernel over a *compacted*
    cache holding exactly the participating tokens (addressing and
    dim-selection cancel; only stage-1 set semantics are judged), and a
    full participation table must be bit-identical to the plain paged
    kernel. The serving row drives a hierarchical engine against the
    full paged engine on the same trace (greedy token_match, gated).
    """
    import math

    from repro.configs import reduced
    from repro.configs.base import ServingConfig, SparsitySpec
    from repro.core import selection
    from repro.core.calibration import identity_projections
    from repro.kernels.ops import aqua_decode, aqua_paged_decode, block_counts
    from repro.serving import ContinuousBatchingEngine, poisson_trace

    rows: List[Row] = []

    # -- structural byte accounting (paper-scale attention geometry) ------
    kvh, d, ps = 8, 128, 128            # kv heads, head dim, page size
    kr, bd = 0.5, 8                      # AQUA dim-block config
    nb, nb_sel = block_counts(d, kr, bd)
    dim_frac = nb_sel / nb               # fraction of khat dims streamed
    q_blk = k_blk = 256                  # prefill kernel tiling
    hbm_gbps = 819e9                     # nominal HBM bandwidth (bytes/s)

    for s in (32768, 65536):
        tag = f"{s // 1024}k"
        npl = s // ps
        tok_bytes = kvh * d * 2          # one bf16 token slot, K or V
        dense = s * tok_bytes * 2        # full K + V stream per decoded tok
        paged = s * tok_bytes * (dim_frac + 1.0)
        rows.append((f"lc/decode_contiguous@{tag}", 0.0,
                     f"bytes_per_tok={dense:.0f} hbm_bytes_ratio=1.000"))
        rows.append((f"lc/decode_paged@{tag}", 0.0,
                     f"bytes_per_tok={paged:.0f} "
                     f"hbm_bytes_ratio={paged / dense:.3f}"))
        hier_bytes = []
        for ratio in (0.5, 0.25, 0.125):
            kp = SparsitySpec(page_keep_ratio=ratio).kept_pages(npl)
            hb = kp * ps * tok_bytes * (dim_frac + 1.0)
            hier_bytes.append(hb)
            rows.append((f"lc/decode_hier@{tag}_r{ratio}", 0.0,
                         f"keep_ratio={ratio} kept_pages={kp} "
                         f"bytes_per_tok={hb:.0f} "
                         f"hbm_bytes_ratio={hb / dense:.3f}"))
        assert all(a > b for a, b in zip(hier_bytes, hier_bytes[1:])), \
            f"gated decode bytes not monotone in keep ratio: {hier_bytes}"

        # prefill: causal k-tile rectangle vs per-q-tile participation.
        # Per-tile bytes (khat dim-blocks + V) are a common factor, so the
        # tile-count ratio IS the byte ratio.
        nqc = s // q_blk
        causal_tiles = sum(qi + 1 for qi in range(nqc))
        rows.append((f"lc/prefill_paged@{tag}", 0.0,
                     f"ktiles={causal_tiles} hbm_bytes_ratio=1.000"))
        for ratio in (0.5, 0.25):
            kept_tiles = max(math.ceil(ratio * (s // k_blk)), 2)
            hier_tiles = sum(min(kept_tiles, qi + 1) for qi in range(nqc))
            rows.append((f"lc/prefill_hier@{tag}_r{ratio}", 0.0,
                         f"keep_ratio={ratio} ktiles={hier_tiles} "
                         f"hbm_bytes_ratio={hier_tiles / causal_tiles:.3f}"))

        # roofline: decode attention at long context is memory-bound --
        # ~4 flops per streamed khat/V element vs 2 bytes means the
        # arithmetic intensity sits far below any MXU ridge point, so
        # bytes/BW is the step-time floor and the hierarchical win is the
        # byte ratio itself.
        kp8 = SparsitySpec(page_keep_ratio=0.125).kept_pages(npl)
        hb8 = kp8 * ps * tok_bytes * (dim_frac + 1.0)
        rows.append((f"lc/roofline_decode@{tag}", 0.0,
                     f"bound=memory ai_flops_per_byte=2.0 "
                     f"t_dense_ms={dense / hbm_gbps * 1e3:.3f} "
                     f"t_paged_ms={paged / hbm_gbps * 1e3:.3f} "
                     f"t_hier_r0.125_ms={hb8 / hbm_gbps * 1e3:.3f} "
                     f"speedup={dense / hb8:.1f}x"))

    # -- executed kernel fidelity (reduced long geometry) -----------------
    b, h, kvh, s, d = 1, 4, 2, 2048, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.normal(ks[0], (b, h, d))
    khat = jax.random.normal(ks[1], (b, kvh, s, d))
    v = jax.random.normal(ks[2], (b, kvh, s, d))
    lengths = jnp.full((b,), s, jnp.int32)
    npg = s // 128
    perm = np.arange(npg, dtype=np.int32)[::-1].copy()
    pages_k = khat[0].reshape(kvh, npg, 128, d).transpose(1, 0, 2, 3)
    pages_v = v[0].reshape(kvh, npg, 128, d).transpose(1, 0, 2, 3)
    pool_k = jnp.zeros_like(pages_k).at[perm].set(pages_k)
    pool_v = jnp.zeros_like(pages_v).at[perm].set(pages_v)
    table = jnp.asarray(perm)[None]

    # full participation table == the plain paged kernel, bit for bit
    ident_part = jnp.arange(npg, dtype=jnp.int32)[None]
    out_full = aqua_paged_decode(q, pool_k, pool_v, table, lengths,
                                 part_idx=ident_part, k_ratio=kr,
                                 block_dims=bd, seq_blk=128)
    out_plain = aqua_paged_decode(q, pool_k, pool_v, table, lengths,
                                  k_ratio=kr, block_dims=bd, seq_blk=128)
    err = float(jnp.max(jnp.abs(out_full - out_plain)))
    assert err == 0.0, \
        f"full participation table is not bit-identical to paged: {err}"
    rows.append(("lc/hier_identity_full_keep", 0.0,
                 f"max_abs_err={err:.2e}"))

    # H2O-mass-ranked subset vs the contiguous kernel over a compacted
    # cache of exactly the participating tokens (same dim selection, same
    # softmax set -- only the stage-1 addressing is under test)
    acc = jax.random.uniform(ks[3], (npg, kvh, 128))   # physical-page mass
    kp = 6
    part = selection.participating_pages(
        acc, table, jnp.full((b,), s, jnp.int32), page_size=128,
        kept_pages=kp, pin_recent_pages=2)
    out_h = aqua_paged_decode(q, pool_k, pool_v, table, lengths,
                              part_idx=part, k_ratio=kr, block_dims=bd,
                              seq_blk=128)
    sel_tok = (part[0][:, None] * 128
               + jnp.arange(128)[None, :]).reshape(-1)
    out_ref = aqua_decode(q, khat[:, :, sel_tok, :], v[:, :, sel_tok, :],
                          jnp.full((b,), kp * 128, jnp.int32), k_ratio=kr,
                          block_dims=bd)
    err = float(jnp.max(jnp.abs(out_h - out_ref)))
    rows.append((f"lc/hier_decode_k{kr}_kp{kp}of{npg}", 0.0,
                 f"max_abs_err={err:.2e}"))

    # -- serving-level greedy identity ------------------------------------
    cfg = dataclasses.replace(reduced("qwen3-0.6b"), remat=False,
                              dtype="float32",
                              aqua=AquaConfig(k_ratio=0.5, block_dims=8))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    ident = identity_projections(cfg.num_layers, cfg.attention.num_kv_heads,
                                 cfg.attention.head_dim)
    # prompts long enough that lanes grow past the 4-page keep budget
    # (up to 38 tokens = 5 pages of 8), so stage 1 genuinely drops pages
    # mid-stream instead of trivially covering the whole context
    reqs = poisson_trace(8, mean_interarrival=2.0, prompt_lens=(8, 22),
                         max_new_tokens=16, vocab_size=cfg.vocab_size,
                         seed=0)
    scfg = ServingConfig(max_lanes=4, max_seq=64, max_new_tokens=16,
                         prompt_bucket=8,
                         cache=CacheSpec(page_size=8, num_pages=34))
    ref = ContinuousBatchingEngine(cfg, params, ident, serving=scfg,
                                   backend="aqua-block-sparse").run(reqs)
    hcfg = dataclasses.replace(
        scfg, sparsity=SparsitySpec(page_keep_ratio=0.5))
    eng = ContinuousBatchingEngine(cfg, params, ident, serving=hcfg,
                                   backend="aqua-block-sparse")
    plan = eng.dispatch_plan()
    assert plan.token_sparsity == "hierarchical", \
        f"hierarchical serving bench row lost token sparsity: {plan}"
    assert eng.kept_pages == 4, eng.kept_pages
    out = eng.run(reqs)
    total = match = 0
    for uid, o in ref.items():
        want, got = list(o.tokens), list(out[uid].tokens)
        total += len(want)
        match += sum(a == b_ for a, b_ in zip(want, got))
    rows.append(("lc/serving_hier_r0.5", 0.0,
                 f"kept_pages=4 token_match={match / total:.3f}"))
    return rows
