"""Shared benchmark fixtures: a small trained model + calibrated AQUA
projections, cached on disk so the per-table benches reuse one training
run. CPU-scale stand-in for the paper's Llama-3.1-8B testbed (DESIGN.md
§6 paper-scale note)."""
from __future__ import annotations

import dataclasses
import os
import pickle
import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced
from repro.configs.base import ModelConfig, TrainConfig
from repro.core.calibration import AquaProjections, calibrate
from repro.data.pipeline import DataConfig, calibration_batches, make_batch
from repro.launch.train import Trainer
from repro.models import build_model

CACHE = os.environ.get("REPRO_BENCH_CACHE", "/tmp/repro_bench_cache.pkl")

# GQA-structured small model (kv < heads, like the paper's Llama-3.1 group
# structure) trained on the learnable LCG language.
BENCH_SEQ = 64
BENCH_VOCAB = 128


def bench_config() -> ModelConfig:
    cfg = reduced("qwen3-0.6b", vocab=BENCH_VOCAB, d_model=96)
    return dataclasses.replace(cfg, remat=False, dtype="float32")


def data_config() -> DataConfig:
    # copy task: quality depends on long-range attention, so AQUA's
    # approximation level is visible in the NLL (unlike Markovian data).
    return DataConfig(vocab_size=BENCH_VOCAB, seq_len=BENCH_SEQ,
                      global_batch=16, kind="copy")


def get_trained_model() -> Tuple[ModelConfig, dict, AquaProjections]:
    if os.path.exists(CACHE):
        with open(CACHE, "rb") as f:
            cfg, params, proj = pickle.load(f)
        return cfg, jax.tree.map(jnp.asarray, params), \
            AquaProjections(p=jnp.asarray(proj))
    cfg = bench_config()
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=20, total_steps=400)
    trainer = Trainer(cfg, tcfg, data_config(), donate=False)
    state, _ = trainer.run(400, log_every=100)
    params = state.params
    model = build_model(cfg)

    def fwd_cap(p, batch):
        _, aux = model.forward(p, batch, capture=True)
        return aux
    proj = calibrate(fwd_cap, params,
                     calibration_batches(cfg, num_batches=4, batch=4,
                                         seq=BENCH_SEQ), cfg)
    with open(CACHE, "wb") as f:
        pickle.dump((cfg, jax.tree.map(np.asarray, params),
                     np.asarray(proj.p)), f)
    return cfg, params, proj


def eval_nll(cfg: ModelConfig, params, proj, *, steps=4, seed0=50_000
             ) -> float:
    """Teacher-forced NLL on held-out batches under an AQUA config."""
    from repro.models.layers import cross_entropy
    model = build_model(cfg)
    p_arr = None if proj is None else proj.p
    fwd = jax.jit(lambda pr, b: cross_entropy(
        model.forward(pr, b, aqua_proj=p_arr), b["labels"],
        b.get("loss_mask")))
    dcfg = data_config()
    vals = [float(fwd(params, make_batch(dcfg, seed0 + i)))
            for i in range(steps)]
    return float(np.mean(vals))


def timeit(fn, *args, iters: int = 5) -> float:
    """Median wall time in microseconds (jit-compiled callable)."""
    fn(*args)  # warmup/compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))
