"""Continuous-batching serve-stack tests: staggered-arrival scheduling
must be token-identical to solo serving (dense + AQUA backends, H2O on),
lane surgery must be leak-free, and the H2O keep-set must track the
``h2o.reference_keep_set`` oracle through the lane-reset path.

The ``slow`` variants run the same checks at full size (more lanes,
requests, and tokens); CI runs ``pytest -m "not slow"``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.configs.base import AquaConfig, ServingConfig
from repro.core.calibration import identity_projections
from repro.core.h2o import reference_keep_set
from repro.models import build_model
from repro.serving import (ContinuousBatchingEngine, LaneScheduler, Request,
                           ServeEngine, poisson_trace)

POLICIES = {
    "dense-jnp": dict(aqua=None, backend="dense-jnp"),
    "aqua-masked-dense": dict(aqua=AquaConfig(k_ratio=0.75, block_dims=1),
                              backend="aqua-masked-dense"),
    "aqua-h2o": dict(aqua=AquaConfig(k_ratio=0.75, h2o_ratio=0.5,
                                     block_dims=1),
                     backend="aqua-masked-dense"),
}


@pytest.fixture(scope="module")
def dense_model():
    cfg = dataclasses.replace(reduced("qwen3-0.6b"), remat=False,
                              dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _engines(dense_model, policy, scfg):
    cfg, params = dense_model
    spec = POLICIES[policy]
    cfg = dataclasses.replace(cfg, aqua=spec["aqua"])
    proj = None
    if spec["aqua"] is not None:
        proj = identity_projections(cfg.num_layers,
                                    cfg.attention.num_kv_heads,
                                    cfg.attention.head_dim)
    cont = ContinuousBatchingEngine(cfg, params, proj, serving=scfg,
                                    backend=spec["backend"])
    solo = ServeEngine(cfg, params, proj, max_seq=scfg.max_seq,
                       backend=spec["backend"])
    return cont, solo


def _check_equivalence(dense_model, policy, *, num_requests, max_lanes,
                       max_new, seed):
    """Staggered-arrival scheduling == solo rectangular serving at T=0."""
    cfg, _ = dense_model
    scfg = ServingConfig(max_lanes=max_lanes, max_seq=64,
                         max_new_tokens=max_new, prompt_bucket=8)
    cont, solo = _engines(dense_model, policy, scfg)
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        size=(int(rng.integers(4, 22)),),
                                        dtype=np.int32),
                    max_new_tokens=max_new, arrival=float(i) * 1.5)
            for i in range(num_requests)]
    outs = cont.run(reqs)
    assert len(outs) == num_requests
    for r in reqs:
        ref = solo.generate(
            {"tokens": jnp.asarray(np.asarray(r.tokens)[None])},
            steps=max_new)
        np.testing.assert_array_equal(
            np.asarray(outs[r.uid].tokens), ref.tokens[0],
            err_msg=f"policy={policy} uid={r.uid}")
    # staggered arrivals with enough lanes must actually overlap
    assert cont.stats.mean_occupancy > 1.0, cont.stats


@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_scheduler_equivalence(dense_model, policy):
    _check_equivalence(dense_model, policy, num_requests=4, max_lanes=3,
                       max_new=6, seed=0)


@pytest.mark.slow
@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_scheduler_equivalence_full(dense_model, policy):
    _check_equivalence(dense_model, policy, num_requests=10, max_lanes=4,
                       max_new=16, seed=1)


def test_lane_insert_and_reset_are_isolated(dense_model):
    """insert_lane grafts a B=1 prefill cache into exactly one batch row;
    reset_lane restores the empty-cache condition; other lanes untouched."""
    cfg, params = dense_model
    model = build_model(cfg)
    max_seq = 32
    state = model.init_decode_state(3, max_seq)
    toks = jnp.arange(1, 9, dtype=jnp.int32)[None]
    _, req = jax.jit(lambda p, b: model.prefill(p, b, max_seq))(
        params, {"tokens": toks})
    before = jax.tree.map(np.asarray, state)
    after = model.insert_lane(state, req, jnp.int32(1))
    for dst, src, orig in zip(jax.tree.leaves(after.layers),
                              jax.tree.leaves(req.layers),
                              jax.tree.leaves(before.layers)):
        np.testing.assert_array_equal(np.asarray(dst)[:, 1], src[:, 0])
        np.testing.assert_array_equal(np.asarray(dst)[:, 0], orig[:, 0])
        np.testing.assert_array_equal(np.asarray(dst)[:, 2], orig[:, 2])
    reset = model.reset_lane(after, jnp.int32(1), max_seq)
    for dst, orig in zip(jax.tree.leaves(reset.layers),
                         jax.tree.leaves(before.layers)):
        np.testing.assert_array_equal(np.asarray(dst), orig)


def test_write_mask_freezes_inactive_lanes(dense_model):
    """decode_step with write_mask must leave masked-off rows' cache
    bit-identical (count, K/V, positions, acc_score)."""
    cfg, params = dense_model
    model = build_model(cfg)
    _, state = model.prefill(params, {"tokens": jnp.ones((2, 6), jnp.int32)},
                             32)
    toks = jnp.array([3, 4], jnp.int32)
    _, st2 = model.decode_step(params, state, toks,
                               write_mask=jnp.array([True, False]))
    for new, old in zip(jax.tree.leaves(st2.layers),
                        jax.tree.leaves(state.layers)):
        np.testing.assert_array_equal(np.asarray(new)[:, 1],
                                      np.asarray(old)[:, 1])
    # the unmasked row did advance
    assert int(st2.layers.count[0, 0]) == int(state.layers.count[0, 0]) + 1


def test_h2o_keep_set_tracks_oracle_through_lane_reset(dense_model):
    """Serve request A then request B through the SAME lane (max_lanes=1
    forces the reset/overwrite path). B's terminal H2O cache must (a) be
    bit-identical to serving B on a fresh engine — no leakage of A's
    acc_score/positions through the lane handoff — and (b) agree with the
    ``reference_keep_set`` oracle computed from B's full-attention weight
    history: the recent window exactly, the heavy hitters by majority."""
    cfg, params = dense_model
    cfg = dataclasses.replace(
        cfg, num_layers=1,
        aqua=AquaConfig(k_ratio=1.0, h2o_ratio=0.25, block_dims=1))
    # single-layer params: the oracle weight history is unambiguous
    model = build_model(cfg)
    params1 = model.init(jax.random.PRNGKey(0))
    proj = identity_projections(1, cfg.attention.num_kv_heads,
                                cfg.attention.head_dim)
    max_seq, max_new = 32, 8
    budget = max(8, int(0.25 * max_seq))
    scfg = ServingConfig(max_lanes=1, max_seq=max_seq,
                         max_new_tokens=max_new)
    rng = np.random.default_rng(7)
    prompt_a = rng.integers(0, cfg.vocab_size, size=(14,), dtype=np.int32)
    prompt_b = rng.integers(0, cfg.vocab_size, size=(12,), dtype=np.int32)

    eng = ContinuousBatchingEngine(cfg, params1, proj, serving=scfg,
                                   backend="aqua-masked-dense")
    outs = eng.run([Request(uid=0, tokens=prompt_a, arrival=0.0),
                    Request(uid=1, tokens=prompt_b, arrival=1.0)])
    reused = jax.tree.map(np.asarray, eng.last_state)

    fresh_eng = ContinuousBatchingEngine(cfg, params1, proj, serving=scfg,
                                         backend="aqua-masked-dense")
    fresh_outs = fresh_eng.run([Request(uid=1, tokens=prompt_b)])
    fresh = jax.tree.map(np.asarray, fresh_eng.last_state)

    # (a) lane handoff is leak-free: identical tokens AND identical cache
    np.testing.assert_array_equal(outs[1].tokens, fresh_outs[1].tokens)
    for a, b in zip(jax.tree.leaves(reused.layers),
                    jax.tree.leaves(fresh.layers)):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)

    # (b) oracle: full-attention weight history of B's realized sequence
    seq = np.concatenate([prompt_b, np.asarray(outs[1].tokens[:-1])])
    _, aux = model.forward(params1, {"tokens": jnp.asarray(seq)[None]},
                           capture=True)
    q, k = aux["qk"][0]
    d = q.shape[-1]
    sc = jnp.einsum("bskgd,btkd->bkgst", q, k) / np.sqrt(d)
    s = seq.shape[0]
    causal = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
    w = jax.nn.softmax(jnp.where(causal[None, None, None], sc, -1e30), -1)
    w_tok = np.asarray(w.sum(axis=(1, 2)))[0]          # (S_q, S_k)
    kept_oracle = set(np.asarray(
        reference_keep_set(jnp.asarray(w_tok), budget,
                           AquaConfig().h2o_recent_frac)).tolist())
    kept_online = set(int(p) for p in reused.layers.positions[0, 0]
                      if p >= 0)
    assert len(kept_online) == budget
    recent = max(1, int(AquaConfig().h2o_recent_frac * budget))
    # recent window: exact agreement by construction
    for p in range(s - recent, s):
        assert p in kept_online, (p, sorted(kept_online))
    # heavy hitters: online approximation must agree on the majority
    assert len(kept_online & kept_oracle) >= budget // 2 + 1, (
        sorted(kept_online), sorted(kept_oracle))


def test_eos_and_length_stop_detection(dense_model):
    cfg, params = dense_model
    scfg = ServingConfig(max_lanes=2, max_seq=64, max_new_tokens=5,
                         prompt_bucket=8)
    eng = ContinuousBatchingEngine(cfg, params, None, serving=scfg)
    # find the greedy first token of this prompt, then use it as eos_id
    solo = ServeEngine(cfg, params, None, max_seq=64)
    prompt = np.arange(4, dtype=np.int32)
    first = int(solo.generate({"tokens": jnp.asarray(prompt[None])},
                              steps=1).tokens[0, 0])
    outs = eng.run([Request(uid=0, tokens=prompt, eos_id=first),
                    Request(uid=1, tokens=prompt, eos_id=-1)])
    assert outs[0].finish_reason == "eos" and len(outs[0].tokens) == 1
    assert outs[1].finish_reason == "length" and len(outs[1].tokens) == 5


def test_top_k_one_is_greedy(dense_model):
    cfg, params = dense_model
    scfg = ServingConfig(max_lanes=1, max_seq=64, max_new_tokens=6)
    eng = ContinuousBatchingEngine(cfg, params, None, serving=scfg)
    prompt = np.arange(6, dtype=np.int32)
    hot = eng.run([Request(uid=0, tokens=prompt, temperature=1.0, top_k=1)])
    greedy = eng.run([Request(uid=0, tokens=prompt, temperature=0.0)])
    np.testing.assert_array_equal(hot[0].tokens, greedy[0].tokens)


def test_poisson_trace_overlaps_lanes(dense_model):
    """Acceptance: on a Poisson trace the scheduler sustains >1 mean lane
    occupancy — the rectangular engine structurally cannot overlap."""
    cfg, params = dense_model
    scfg = ServingConfig(max_lanes=4, max_seq=64, max_new_tokens=10,
                         prompt_bucket=8)
    eng = ContinuousBatchingEngine(cfg, params, None, serving=scfg)
    reqs = poisson_trace(8, mean_interarrival=2.0, prompt_lens=(6, 10, 14),
                         max_new_tokens=10, vocab_size=cfg.vocab_size,
                         seed=3)
    outs = eng.run(reqs)
    assert all(len(o.tokens) == 10 for o in outs.values())
    assert eng.stats.mean_occupancy > 1.0, eng.stats
    assert eng.stats.requests_finished == 8


def test_streaming_event_order(dense_model):
    """Per-request token indices stream in order 0,1,2,... and exactly one
    finished event per request."""
    cfg, params = dense_model
    scfg = ServingConfig(max_lanes=2, max_seq=64, max_new_tokens=4,
                         prompt_bucket=8)
    eng = ContinuousBatchingEngine(cfg, params, None, serving=scfg)
    reqs = [Request(uid=i, tokens=np.arange(4 + i, dtype=np.int32),
                    arrival=float(i)) for i in range(3)]
    seen, finished = {}, set()
    for ev in eng.serve(reqs):
        assert ev.index == seen.get(ev.uid, 0)
        seen[ev.uid] = ev.index + 1
        if ev.finished:
            assert ev.uid not in finished
            finished.add(ev.uid)
    assert finished == {0, 1, 2} and all(v == 4 for v in seen.values())


def test_prompt_bucket_never_pads_past_max_seq(dense_model):
    """Regression: a prompt landing in the last partial bucket must not be
    padded past max_seq — that would roll the prompt prefix out of the
    slot cache during admission and silently corrupt generations."""
    cfg, params = dense_model
    scfg = ServingConfig(max_lanes=1, max_seq=20, max_new_tokens=2,
                         prompt_bucket=16)
    eng = ContinuousBatchingEngine(cfg, params, None, serving=scfg)
    prompt = np.arange(18, dtype=np.int32) % cfg.vocab_size
    outs = eng.run([Request(uid=0, tokens=prompt)])
    pos = np.asarray(eng.last_state.layers.positions)[0, 0]
    assert 0 in pos and pos.max() < 20          # prefix kept, no phantoms
    solo = ServeEngine(cfg, params, None, max_seq=20)
    ref = solo.generate({"tokens": jnp.asarray(prompt[None])}, steps=2)
    np.testing.assert_array_equal(np.asarray(outs[0].tokens), ref.tokens[0])


def test_request_validation(dense_model):
    cfg, params = dense_model
    scfg = ServingConfig(max_lanes=1, max_seq=16, max_new_tokens=8)
    eng = ContinuousBatchingEngine(cfg, params, None, serving=scfg)
    with pytest.raises(ValueError, match="exceeds"):
        eng.run([Request(uid=0, tokens=np.arange(12, dtype=np.int32))])
    with pytest.raises(ValueError, match="empty"):
        eng.run([Request(uid=0, tokens=np.zeros((0,), np.int32))])


@pytest.mark.parametrize("arch", ["mamba2-370m", "recurrentgemma-9b"])
def test_nonattention_families_serve_through_lanes(arch):
    """SSM and hybrid families ride the same lane machinery (the hybrid's
    unstacked per-layer caches exercise the axis-0 insert_lane override)
    and stay solo-equivalent at temperature 0."""
    cfg = dataclasses.replace(reduced(arch), remat=False, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    scfg = ServingConfig(max_lanes=2, max_seq=32, max_new_tokens=4)
    eng = ContinuousBatchingEngine(cfg, params, None, serving=scfg)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab_size, size=(4 + 2 * i,),
                                        dtype=np.int32),
                    arrival=float(i)) for i in range(3)]
    outs = eng.run(reqs)
    solo = ServeEngine(cfg, params, None, max_seq=32)
    for r in reqs:
        ref = solo.generate(
            {"tokens": jnp.asarray(np.asarray(r.tokens)[None])}, steps=4)
        np.testing.assert_array_equal(np.asarray(outs[r.uid].tokens),
                                      ref.tokens[0])
    assert eng.stats.mean_occupancy > 1.0


def test_lane_scheduler_bookkeeping():
    sched = LaneScheduler(2)
    for i, t in enumerate((0.0, 0.5, 3.0)):
        sched.submit(Request(uid=i, tokens=np.zeros((4,), np.int32),
                             arrival=t))
    r0 = sched.pop_admissible(0.0)
    assert r0.uid == 0
    lane0 = sched.assign(r0)
    assert sched.pop_admissible(0.0) is None          # uid=1 not arrived yet
    r1 = sched.pop_admissible(1.0)
    assert r1.uid == 1
    lane1 = sched.assign(r1)
    assert {lane0, lane1} == {0, 1}
    assert sched.pop_admissible(10.0) is None         # lanes full
    assert sched.num_active == 2 and sched.has_pending
    sched.retire(lane1)
    assert sched.pop_admissible(2.0) is None          # uid=2 not arrived
    assert sched.pop_admissible(3.0).uid == 2
    assert sched.request_in(lane0).uid == 0
