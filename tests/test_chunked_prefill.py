"""Chunked-prefill/decode interleaving: token identity vs the
monolithic-admit engine (chunking must change *when* work happens, never
*what* is computed), the chunk-resumable kernel entry, and the bounded
head-of-line admission lookahead.

Greedy identity is checked for every backend × cache-layout × mesh
combination the interleaved path serves: the jnp reference backends use
per-query dim selection (position-pure, trivially chunk-invariant) while
``aqua-block-sparse`` reproduces the kernel's per-tile |q̂| aggregation
(``attention._chunk_tile_mask``), which requires the budget to land on
``prefill_q_blk`` tile boundaries — the geometry the dispatch plan's
``REASON_CHUNK_GEOMETRY`` gate enforces.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.configs.base import AquaConfig, CacheSpec, ServingConfig
from repro.core.calibration import identity_projections
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, Request


@pytest.fixture(scope="module")
def dense_model():
    cfg = dataclasses.replace(reduced("qwen3-0.6b"), remat=False,
                              dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _trace(cfg, n=5, max_new=6, seed=3, lo=20, hi=60):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        size=(int(rng.integers(lo, hi)),),
                                        dtype=np.int32),
                    max_new_tokens=max_new, arrival=float(i) * 0.25)
            for i in range(n)]


SCFG = ServingConfig(max_lanes=4, max_seq=96, max_new_tokens=6,
                     prompt_bucket=8)
PSCFG = dataclasses.replace(SCFG, cache=CacheSpec(page_size=8, num_pages=48))

# budget 16 < every padded prompt in the trace, so admissions really
# chunk; prefill_q_blk=16 keeps the block-sparse kernel's selection
# tiles on chunk boundaries (else the plan falls back to monolithic)
POLICIES = {
    "dense-jnp": dict(aqua=None, backend="dense-jnp"),
    "aqua-masked-dense": dict(
        aqua=AquaConfig(k_ratio=0.75, block_dims=1), backend="aqua-masked-dense"),
    "aqua-block-sparse": dict(
        aqua=AquaConfig(k_ratio=0.5, block_dims=8, prefill_q_blk=16),
        backend="aqua-block-sparse"),
}


def _engine(dense_model, policy, scfg, budget=None, mesh=None):
    cfg, params = dense_model
    spec = POLICIES[policy]
    cfg = dataclasses.replace(cfg, aqua=spec["aqua"])
    if budget is not None:
        scfg = dataclasses.replace(scfg, prefill_budget_tokens=budget)
    proj = None
    if spec["aqua"] is not None:
        proj = identity_projections(cfg.num_layers,
                                    cfg.attention.num_kv_heads,
                                    cfg.attention.head_dim)
    return ContinuousBatchingEngine(cfg, params, proj, serving=scfg,
                                    backend=spec["backend"], mesh=mesh)


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("policy", list(POLICIES))
def test_chunked_token_identity(dense_model, policy, layout):
    """Greedy tokens from an interleaved drive must be identical to the
    monolithic-admit engine for every backend × cache layout."""
    cfg, _ = dense_model
    scfg = SCFG if layout == "contiguous" else PSCFG
    reqs = _trace(cfg)
    mono = _engine(dense_model, policy, scfg)
    chunk = _engine(dense_model, policy, scfg, budget=16)
    plan = chunk.dispatch_plan()
    assert plan.chunked_prefill, plan.chunked_reasons
    outs_m = mono.run([dataclasses.replace(r) for r in reqs])
    outs_c = chunk.run([dataclasses.replace(r) for r in reqs])
    for uid in outs_m:
        assert outs_m[uid].tokens == outs_c[uid].tokens, (policy, layout, uid)
    st = chunk.stats
    assert st.chunked_admissions == len(reqs)
    assert st.prefill_chunks > st.chunked_admissions  # really interleaved


def test_chunk_geometry_guard(dense_model):
    """A budget off the kernel's q-chunk tile must keep monolithic
    admission (attributed), not silently change the selection."""
    from repro.core.dispatch import REASON_CHUNK_GEOMETRY
    eng = _engine(dense_model, "aqua-block-sparse",
                  dataclasses.replace(SCFG, prompt_bucket=8), budget=24)
    plan = eng.dispatch_plan()
    assert not plan.chunked_prefill
    assert REASON_CHUNK_GEOMETRY in plan.chunked_reasons


@pytest.mark.parametrize("policy", ["dense-jnp", "aqua-block-sparse"])
def test_chunked_token_identity_mesh2x2(dense_model, policy):
    """Interleaving under the serving mesh (incl. the shard_mapped
    kernel path) serves the same greedy tokens as monolithic admission."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 forced host devices")
    from repro.launch.mesh import make_serving_mesh
    cfg, _ = dense_model
    reqs = _trace(cfg)
    mesh = make_serving_mesh((2, 2))
    mono = _engine(dense_model, policy, SCFG, mesh=mesh)
    chunk = _engine(dense_model, policy, SCFG, budget=16, mesh=mesh)
    assert chunk.dispatch_plan().chunked_prefill
    if policy == "aqua-block-sparse":
        assert chunk.dispatch_plan().mesh_native
    outs_m = mono.run([dataclasses.replace(r) for r in reqs])
    outs_c = chunk.run([dataclasses.replace(r) for r in reqs])
    for uid in outs_m:
        assert outs_m[uid].tokens == outs_c[uid].tokens, (policy, uid)
    if policy == "aqua-block-sparse":
        assert chunk.mesh_fallback_events() == ()


def test_hol_lookahead_admits_small_after_blocked_head(dense_model):
    """When the pool can't fit the queue head, a later small request may
    admit first (bounded first-fit); strict FIFO (lookahead=1) keeps the
    old head-of-line blocking. Token outputs are identical either way."""
    cfg, _ = dense_model
    scfg = ServingConfig(max_lanes=3, max_seq=64, max_new_tokens=10,
                         prompt_bucket=8,
                         cache=CacheSpec(page_size=8, num_pages=9,
                                         prefix_sharing=False))
    rng = np.random.default_rng(11)

    def mk(uid, n, arrival, max_new=10):
        return Request(uid=uid,
                       tokens=rng.integers(0, cfg.vocab_size, size=(n,),
                                           dtype=np.int32),
                       max_new_tokens=max_new, arrival=arrival)
    # A reserves 5 of 9 pages; B (5 pages) can't fit while A is live;
    # C (2 pages) can.
    reqs = [mk(0, 30, 0.0), mk(1, 30, 0.0), mk(2, 8, 0.0, max_new=4)]

    def first_emission_order(lookahead):
        eng = _engine(dense_model, "dense-jnp",
                      dataclasses.replace(scfg,
                                          admission_lookahead=lookahead))
        seen, outs = [], {}
        for ev in eng.serve([dataclasses.replace(r) for r in reqs]):
            if ev.uid not in seen:
                seen.append(ev.uid)
            outs.setdefault(ev.uid, []).append(ev.token)
        return seen, outs

    fifo_order, fifo_outs = first_emission_order(1)
    la_order, la_outs = first_emission_order(4)
    # strict FIFO: the blocked head (uid 1) holds uid 2 back
    assert fifo_order.index(1) < fifo_order.index(2)
    # lookahead: the small request overtakes the blocked head
    assert la_order.index(2) < la_order.index(1)
    assert fifo_outs == la_outs   # admission order never changes tokens


# -- chunk-resumable kernel entry ------------------------------------------


def test_prefill_chunk_aligned_bitwise():
    """q_blk-aligned chunk invocations of the block-sparse kernel are
    bitwise identical to the monolithic call: chunk-local |q̂| tile
    aggregation sees exactly the monolithic tiles, and masked-out key
    tiles are exact no-ops in the online softmax."""
    from repro.kernels.ops import aqua_prefill, aqua_prefill_chunk
    rng = np.random.default_rng(0)
    b, h, kv, s, d = 2, 4, 2, 64, 32
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, kv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, kv, s, 16)), jnp.float32)
    lengths = jnp.asarray([s, 40], jnp.int32)
    kw = dict(k_ratio=0.5, block_dims=8, q_blk=16, k_blk=16)
    ref = aqua_prefill(q, k, v, lengths, **kw)
    for split in (16, 32, 48):
        parts = []
        for lo, hi in ((0, split), (split, s)):
            out, carry = aqua_prefill_chunk(q[:, :, lo:hi], k, v, lengths,
                                            q_offset=lo, **kw)
            parts.append(out)
            assert not np.asarray(carry).any()  # aligned -> no carry
        chunked = jnp.concatenate(parts, axis=2)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(chunked))


def test_prefill_chunk_carry_oracle():
    """A chunk ending mid-tile returns the partial tile's masked |q̂|
    aggregate as carry, and a following chunk folds a passed carry into
    its first tile's selection."""
    from repro.core.aqua import chunk_topk_block_indices
    from repro.kernels.ops import aqua_prefill_chunk
    rng = np.random.default_rng(1)
    b, h, s, d, q_blk, bd = 1, 2, 48, 32, 16, 8
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, 1, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, 1, s, 8)), jnp.float32)
    lengths = jnp.asarray([44], jnp.int32)
    t1 = 24   # mid-tile boundary: tile [16, 32) straddles it
    _, carry = aqua_prefill_chunk(q[:, :, :t1], k, v, lengths, q_offset=0,
                                  k_ratio=0.5, block_dims=bd, q_blk=q_blk,
                                  k_blk=16)
    # oracle: |q̂| of the partial tile's valid rows, summed per dim-block
    rows = np.abs(np.asarray(q[:, :, 16:t1], np.float32))
    oracle = rows.reshape(b, h, t1 - 16, d // bd, bd).sum(axis=(2, 4))
    np.testing.assert_allclose(np.asarray(carry), oracle, rtol=1e-6)
    # a second chunk resuming at a tile boundary must NOT see a carry
    _, carry2 = aqua_prefill_chunk(q[:, :, :32], k, v, lengths, q_offset=0,
                                   k_ratio=0.5, block_dims=bd, q_blk=q_blk,
                                   k_blk=16)
    assert not np.asarray(carry2).any()
    # carry-in shifts the resumed tile's selection to the full-tile
    # aggregate: selection of [16, 32) resumed at row 24 with carry ==
    # monolithic selection of that tile
    full_idx = chunk_topk_block_indices(q[:, :, :32], 16, bd, q_blk,
                                        jnp.minimum(lengths, 32))
    mag2 = np.abs(np.asarray(q[:, :, t1:32], np.float32))
    bmag2 = mag2.reshape(b, h, 32 - t1, d // bd, bd).sum(axis=(2, 4))
    resumed = np.argsort(-(bmag2 + oracle), axis=-1)[..., :2]
    np.testing.assert_array_equal(np.sort(resumed, axis=-1),
                                  np.asarray(full_idx)[:, :, 1])
