"""Import-or-shim for ``hypothesis`` so the tier-1 suite collects and runs
on a bare install (no test extras).

When hypothesis is available it is re-exported unchanged. When it is not,
``given``/``settings``/``st`` are replaced by a deterministic fallback:
each ``@given`` test runs over a small fixed set of example combinations
drawn from the same strategies (corners plus LCG-picked interior points),
so every property-test module still executes real assertions instead of
being skipped at collection. Only the strategy surface the suite uses is
shimmed (``st.integers``, ``st.sampled_from``).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import itertools

    _MAX_FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, examples):
            self.examples = list(examples)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            span = max_value - min_value
            vals = {min_value, max_value, min_value + span // 2,
                    min_value + span // 3, min_value + 2 * span // 3}
            return _Strategy(sorted(vals))

        @staticmethod
        def sampled_from(elements):
            return _Strategy(elements)

    st = _Strategies()

    def settings(**_kwargs):
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                keys = sorted(strategies)
                combos = list(itertools.product(
                    *(strategies[k].examples for k in keys)))
                picked = {0, len(combos) - 1}
                state = 0x9E3779B9
                while len(picked) < min(_MAX_FALLBACK_EXAMPLES, len(combos)):
                    state = (state * 1664525 + 1013904223) % 2 ** 32
                    picked.add(state % len(combos))
                for ci in sorted(picked):
                    fn(*args, **dict(zip(keys, combos[ci])), **kwargs)
            # hide the strategy params from pytest's fixture resolution,
            # keeping genuine fixture params (e.g. tmp_path_factory)
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            return wrapper
        return deco
