"""Synthetic-HF-checkpoint fixtures for the loader/calibration/quality
suites.

The generator itself lives in ``repro.checkpoint.fixtures`` (so the
quality bench can import it without reaching into tests/); this module
is the pytest-facing surface: re-exports plus tmp-dir conveniences for
the variants the oracle suite covers (single-file, sharded 2-file
index, tied-embedding, attention-bias, bf16-stored).
"""

from repro.checkpoint.fixtures import (  # noqa: F401
    QWEN3_TINY,
    fixture_state_dict,
    write_hf_fixture,
)
from repro.checkpoint.hf import config_from_hf


def make_fixture(tmp_path, **kw):
    """Write a fixture checkpoint under ``tmp_path``; returns
    (checkpoint dir, repro ModelConfig, raw HF-layout state dict)."""
    outdir = str(tmp_path / "hf_ckpt")
    sd = write_hf_fixture(outdir, **kw)
    return outdir, config_from_hf(outdir), sd
