"""Property tests for the LaneScheduler's admission queue and the
PREFILLING-lane state machine (chunked-prefill interleaving).

Runs under real hypothesis when installed, else the deterministic
fallback in tests/_hypothesis_compat.py (corner + LCG-picked interior
examples) — the invariants execute either way.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.serving import LaneScheduler, Request
from repro.serving.scheduler import LANE_DECODING, LANE_PREFILLING


def _req(uid, arrival, prompt_len=8, max_new=4):
    return Request(uid=uid, tokens=np.zeros((prompt_len,), np.int32),
                   max_new_tokens=max_new, arrival=arrival)


@settings(deadline=None, max_examples=40)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=2, max_value=12),
       skip=st.integers(min_value=0, max_value=11))
def test_unpop_restores_exact_queue_position(seed, n, skip):
    """pop_admissible(skip=k) followed by unpop is a no-op on the queue,
    for any skip position — including among equal arrival times."""
    rng = np.random.default_rng(seed)
    sched = LaneScheduler(max_lanes=2)
    # clustered arrivals force equal-key ties; submission order must hold
    arrivals = sorted(float(x) for x in rng.integers(0, 3, size=n))
    rng.shuffle(arrivals)
    for uid, arr in enumerate(arrivals):
        sched.submit(_req(uid, arr))
    before = [r.uid for r in sched._pending]
    req = sched.pop_admissible(now=10.0, skip=min(skip, n - 1))
    assert req is not None
    sched.unpop(req)
    assert [r.uid for r in sched._pending] == before
    assert sched._keys == sorted(sched._keys)


@settings(deadline=None, max_examples=40)
@given(seed=st.integers(min_value=0, max_value=10_000),
       lanes=st.integers(min_value=1, max_value=4),
       budget=st.sampled_from([8, 16, 32]))
def test_prefilling_state_machine_invariants(seed, lanes, budget):
    """Drive a random mixed workload through the scheduler the way the
    engine does and check, at every step: a lane is never double-
    assigned, per-step prefill spend never exceeds the budget, cursors
    never pass their target, and every admitted request eventually
    decodes and retires."""
    rng = np.random.default_rng(seed)
    sched = LaneScheduler(max_lanes=lanes)
    n = int(rng.integers(3, 10))
    for uid in range(n):
        sched.submit(_req(uid, arrival=float(rng.integers(0, 4)),
                          prompt_len=int(rng.integers(4, 80)),
                          max_new=int(rng.integers(1, 4))))
    decoded_steps = {}
    retired = set()
    now, steps = 0.0, 0
    while sched.has_work:
        steps += 1
        assert steps < 10_000, "scheduler failed to drain"
        # admissions (mirrors the engine: long prompts go PREFILLING)
        while True:
            req = sched.pop_admissible(now)
            if req is None:
                break
            occupied = set(sched.active_lanes())
            lane = sched.assign(req, prefilling=req.prompt_len > budget)
            assert lane not in occupied          # no double-assign
            if sched.lane_state(lane) == LANE_PREFILLING:
                assert sched.prefill_cursor(lane) == 0
                assert sched.prefill_remaining(lane) == req.prompt_len
            else:
                decoded_steps[req.uid] = 0
        # one engine step: spend the chunk budget oldest-first, then
        # decode every DECODING lane
        spent = 0
        for lane in sched.prefilling_lanes():
            rem = sched.prefill_remaining(lane)
            assert rem > 0
            take = min(rem, budget - spent)
            if take == 0:
                break
            sched.advance_prefill(lane, take)
            spent += take
            assert spent <= budget               # budget never exceeded
            assert sched.prefill_cursor(lane) <= \
                sched.request_in(lane).prompt_len
            if sched.prefill_remaining(lane) == 0:
                uid = sched.request_in(lane).uid
                sched.mark_decoding(lane)
                decoded_steps[uid] = 0
        for lane in sched.decoding_lanes():
            req = sched.request_in(lane)
            decoded_steps[req.uid] += 1
            if decoded_steps[req.uid] >= req.max_new_tokens:
                assert sched.retire(lane) is req
                retired.add(req.uid)
        now += 1.0
    # liveness: every admitted request decoded to completion
    assert retired == set(range(n))
    assert sched.num_active == 0 and not sched.prefilling_lanes()


def test_retire_mid_prefill_is_rejected():
    """A PREFILLING lane must finish its chunks before it can retire —
    the state machine refuses the transition outright."""
    sched = LaneScheduler(max_lanes=1)
    sched.submit(_req(0, 0.0, prompt_len=32))
    req = sched.pop_admissible(now=0.0)
    lane = sched.assign(req, prefilling=True)
    with pytest.raises(AssertionError):
        sched.retire(lane)
    sched.advance_prefill(lane, 32)
    sched.mark_decoding(lane)
    assert sched.lane_state(lane) == LANE_DECODING
    assert sched.retire(lane) is req
