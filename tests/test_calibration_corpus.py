"""Calibration-over-real-text tests: the corpus batch source, SVD
determinism, projection orthonormality, identity passthrough for layers
without a QK product, and the k_ratio=1.0 serving-identity contract
(rotating q and k by the same orthonormal P preserves every score, so a
calibrated P at full kept-ratio must not change greedy decoding)."""

import dataclasses

import jax
import numpy as np
import pytest

from hf_fixtures import make_fixture
from repro.checkpoint.hf import load_hf_checkpoint
from repro.configs.base import AquaConfig
from repro.core.calibration import calibrate, identity_projections
from repro.data.pipeline import (DataConfig, calibration_batches,
                                 load_token_corpus, make_batch)
from repro.models import build_model

CORPUS = "corpora/calibration.txt"


def _corpus_cfg(**kw):
    base = dict(vocab_size=256, seq_len=32, global_batch=4, seed=7,
                kind="corpus", corpus_path=CORPUS)
    base.update(kw)
    return DataConfig(**base)


def test_corpus_batches_deterministic_and_stateless():
    cfg = _corpus_cfg()
    a, b = make_batch(cfg, 3), make_batch(cfg, 3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    c = make_batch(cfg, 4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted views of the same window
    np.testing.assert_array_equal(
        np.asarray(a["tokens"])[:, 1:], np.asarray(a["labels"])[:, :-1])


def test_corpus_tokens_within_vocab():
    ids = load_token_corpus(CORPUS, 256)
    assert ids.ndim == 1 and ids.size > 1000
    assert ids.dtype == np.int32
    assert ids.min() >= 0 and ids.max() < 256
    # folding into a smaller vocab keeps bounds
    small = load_token_corpus(CORPUS, 50)
    assert small.min() >= 0 and small.max() < 50
    b = make_batch(_corpus_cfg(vocab_size=50), 0)
    assert int(np.asarray(b["tokens"]).max()) < 50


def test_npy_corpus_source(tmp_path):
    rng = np.random.default_rng(0)
    ids = rng.integers(0, 1000, size=4096).astype(np.int64)
    path = str(tmp_path / "ids.npy")
    np.save(path, ids)
    loaded = load_token_corpus(path, 256)
    np.testing.assert_array_equal(loaded, (ids % 256).astype(np.int32))
    b = make_batch(_corpus_cfg(corpus_path=path), 2)
    assert np.asarray(b["tokens"]).shape == (4, 32)


def test_text_corpus_is_byte_level():
    ids = load_token_corpus(CORPUS, 256)
    with open(CORPUS, "rb") as f:
        raw = np.frombuffer(f.read(), dtype=np.uint8)
    np.testing.assert_array_equal(ids, raw.astype(np.int32))


def test_unsupported_corpus_format(tmp_path):
    p = tmp_path / "corpus.bin"
    p.write_bytes(b"xx")
    with pytest.raises(ValueError, match="format"):
        load_token_corpus(str(p), 256)


@pytest.fixture(scope="module")
def hf_model(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("cal")
    outdir, cfg, _ = make_fixture(tmp)
    params = load_hf_checkpoint(outdir, cfg)
    model = build_model(cfg)

    def fwd_cap(p, batch):
        _, aux = model.forward(p, batch, capture=True)
        return aux

    return cfg, params, fwd_cap


def _calibrate(cfg, params, fwd_cap, **kw):
    batches = list(calibration_batches(cfg, num_batches=2, batch=2, seq=48,
                                       corpus_path=CORPUS, **kw))
    return calibrate(fwd_cap, params, batches, cfg)


def test_calibration_bit_identical_for_same_corpus_and_seed(hf_model):
    cfg, params, fwd_cap = hf_model
    p1 = np.asarray(_calibrate(cfg, params, fwd_cap).p)
    p2 = np.asarray(_calibrate(cfg, params, fwd_cap).p)
    assert np.array_equal(p1, p2)          # bit-identical, not just close
    p3 = np.asarray(_calibrate(cfg, params, fwd_cap, seed=99).p)
    assert not np.array_equal(p1, p3)      # the seed actually reaches it


def test_calibrated_projections_orthonormal(hf_model):
    cfg, params, fwd_cap = hf_model
    proj = _calibrate(cfg, params, fwd_cap)
    p = np.asarray(proj.p)
    a = cfg.attention
    assert p.shape == (cfg.num_layers, a.num_kv_heads, a.head_dim,
                       a.head_dim)
    eye = np.eye(a.head_dim)
    for li in range(p.shape[0]):
        for h in range(p.shape[1]):
            np.testing.assert_allclose(p[li, h].T @ p[li, h], eye,
                                       atol=1e-4)


def test_layers_without_qk_get_identity_entries(hf_model):
    cfg, params, fwd_cap = hf_model

    def fwd_partial(p, batch):
        aux = fwd_cap(p, batch)
        qk = list(aux["qk"])
        qk[0] = None                       # e.g. an SSM block in a hybrid
        return {"qk": qk}

    batches = list(calibration_batches(cfg, num_batches=2, batch=2, seq=48,
                                       corpus_path=CORPUS))
    proj = calibrate(fwd_partial, params, batches, cfg)
    p = np.asarray(proj.p)
    d = cfg.attention.head_dim
    for h in range(cfg.attention.num_kv_heads):
        np.testing.assert_array_equal(p[0, h], np.eye(d, dtype=np.float32))
    # the touched layer is NOT identity
    assert not np.allclose(p[1, 0], np.eye(d))


def test_k1_calibrated_matches_identity_greedy(hf_model):
    """k_ratio=1.0 keeps every rotated dim, and rotations preserve QK
    scores — so serving with the calibrated P must emit exactly the same
    greedy tokens as serving with identity projections."""
    from repro.serving import ServeEngine

    cfg, params, fwd_cap = hf_model
    proj = _calibrate(cfg, params, fwd_cap)
    a = cfg.attention
    ident = identity_projections(cfg.num_layers, a.num_kv_heads, a.head_dim)
    ck = dataclasses.replace(cfg, aqua=AquaConfig(k_ratio=1.0, block_dims=8))
    prompt = {"tokens": np.asarray(
        load_token_corpus(CORPUS, cfg.vocab_size)[None, 100:116])}
    outs = {}
    for name, p in (("calibrated", proj), ("identity", ident)):
        eng = ServeEngine(ck, params, p, max_seq=48)
        outs[name] = np.asarray(eng.generate(prompt, steps=16).tokens)
    np.testing.assert_array_equal(outs["calibrated"], outs["identity"])


def test_k1_identity_matches_no_aqua_greedy(hf_model):
    """Identity projections at k=1.0 are a no-op by construction: the
    serving engine must emit the no-AQUA engine's tokens bit-exactly."""
    from repro.serving import ServeEngine

    cfg, params, _ = hf_model
    a = cfg.attention
    ident = identity_projections(cfg.num_layers, a.num_kv_heads, a.head_dim)
    ck = dataclasses.replace(cfg, aqua=AquaConfig(k_ratio=1.0, block_dims=8))
    prompt = {"tokens": np.asarray(
        load_token_corpus(CORPUS, cfg.vocab_size)[None, 200:216])}
    with_aqua = ServeEngine(ck, params, ident, max_seq=48).generate(
        prompt, steps=16).tokens
    without = ServeEngine(
        dataclasses.replace(cfg, aqua=None), params, None,
        max_seq=48).generate(prompt, steps=16).tokens
    np.testing.assert_array_equal(np.asarray(with_aqua),
                                  np.asarray(without))
