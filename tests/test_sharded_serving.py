"""Mesh-native serving tests (8 forced host devices, see conftest.py).

The continuous-batching engine under a data×model mesh must be
token-identical to the single-device engines at temperature 0 (and at
temperature > 0 — the per-request RNG folds on (uid, token counter), so
sampling is placement-independent), keep its decode state sharded across
admissions (sharding-preserving lane surgery), and serve Pallas-kernel
backends through the shard_mapped kernel path (tests/test_mesh_kernels.py
covers kernel-path token identity and the non-divisible fallback).
"""
import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import reduced
from repro.configs.base import AquaConfig, ServingConfig
from repro.core import attention as attn_mod
from repro.core.calibration import identity_projections
from repro.distributed import sharding as dsh
from repro.models import build_model
from repro.serving import (ContinuousBatchingEngine, LaneScheduler, Request,
                           ServeEngine)

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _mesh(shape=(4, 2)):
    from repro.launch.mesh import make_serving_mesh
    return make_serving_mesh(shape)


@pytest.fixture(scope="module")
def dense_model():
    cfg = dataclasses.replace(reduced("qwen3-0.6b"), remat=False,
                              dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


POLICIES = {
    "dense-jnp": dict(aqua=None, backend="dense-jnp"),
    "aqua-masked-dense": dict(aqua=AquaConfig(k_ratio=0.75, block_dims=1),
                              backend="aqua-masked-dense"),
}


def _mesh_engine(dense_model, policy, scfg, mesh):
    cfg, params = dense_model
    spec = POLICIES[policy]
    cfg = dataclasses.replace(cfg, aqua=spec["aqua"])
    proj = None
    if spec["aqua"] is not None:
        proj = identity_projections(cfg.num_layers,
                                    cfg.attention.num_kv_heads,
                                    cfg.attention.head_dim)
    eng = ContinuousBatchingEngine(cfg, params, proj, serving=scfg,
                                   backend=spec["backend"], mesh=mesh)
    return cfg, proj, eng


def _staggered_trace(cfg, num_requests, max_new, seed):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        size=(int(rng.integers(4, 22)),),
                                        dtype=np.int32),
                    max_new_tokens=max_new, arrival=float(i) * 1.5)
            for i in range(num_requests)]


@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_staggered_equivalence_on_8_device_mesh(dense_model, policy):
    """Staggered arrivals on a 4×2 data×model mesh == solo rectangular
    serving, token for token at temperature 0."""
    scfg = ServingConfig(max_lanes=4, max_seq=64, max_new_tokens=6,
                         prompt_bucket=8)
    cfg, proj, eng = _mesh_engine(dense_model, policy, scfg, _mesh((4, 2)))
    reqs = _staggered_trace(cfg, num_requests=4, max_new=6, seed=0)
    outs = eng.run(reqs)
    solo = ServeEngine(cfg, dense_model[1], proj, max_seq=scfg.max_seq,
                       backend=POLICIES[policy]["backend"])
    for r in reqs:
        ref = solo.generate(
            {"tokens": jnp.asarray(np.asarray(r.tokens)[None])}, steps=6)
        np.testing.assert_array_equal(
            np.asarray(outs[r.uid].tokens), ref.tokens[0],
            err_msg=f"policy={policy} uid={r.uid}")
    assert eng.stats.mean_occupancy > 1.0, eng.stats


def test_sampling_is_lane_placement_independent_on_mesh(dense_model):
    """temperature > 0 on the mesh: the RNG folds on (uid, token counter),
    so a request samples the same tokens whether it shares the mesh with
    staggered co-tenants or is served alone. (Cross-*partitioning* token
    equality is only guaranteed at temperature 0 — resharding the model
    axis reorders float reductions, and Gumbel sampling amplifies ulp
    differences — so the solo reference runs on the same mesh.)"""
    cfg, params = dense_model
    scfg = ServingConfig(max_lanes=4, max_seq=64, max_new_tokens=5,
                         prompt_bucket=8)
    reqs = _staggered_trace(cfg, num_requests=2, max_new=5, seed=2)
    for r in reqs:
        r.temperature = 1.0
    mesh = _mesh((4, 2))
    batched = ContinuousBatchingEngine(cfg, params, None, serving=scfg,
                                       backend="dense-jnp", mesh=mesh)
    b_outs = batched.run(reqs)
    for r in reqs:
        # fresh engine per request: serve-key fold counter starts at 0,
        # matching the batched drive's serve-level key
        solo = ContinuousBatchingEngine(cfg, params, None, serving=scfg,
                                        backend="dense-jnp", mesh=mesh)
        s_out = solo.run([dataclasses.replace(r, arrival=0.0)])
        np.testing.assert_array_equal(b_outs[r.uid].tokens,
                                      s_out[r.uid].tokens)


def test_h2o_equivalence_on_mesh(dense_model):
    """H2O eviction state (acc_score) shards over the mesh and stays
    solo-equivalent through the exact-length admission path."""
    cfg, params = dense_model
    cfg = dataclasses.replace(cfg, aqua=AquaConfig(k_ratio=0.75,
                                                   h2o_ratio=0.5,
                                                   block_dims=1))
    proj = identity_projections(cfg.num_layers, cfg.attention.num_kv_heads,
                                cfg.attention.head_dim)
    scfg = ServingConfig(max_lanes=4, max_seq=64, max_new_tokens=5,
                         prompt_bucket=8)
    eng = ContinuousBatchingEngine(cfg, params, proj, serving=scfg,
                                   backend="aqua-masked-dense",
                                   mesh=_mesh((2, 2)))
    reqs = _staggered_trace(cfg, num_requests=3, max_new=5, seed=1)
    outs = eng.run(reqs)
    solo = ServeEngine(cfg, params, proj, max_seq=64,
                       backend="aqua-masked-dense")
    for r in reqs:
        ref = solo.generate(
            {"tokens": jnp.asarray(np.asarray(r.tokens)[None])}, steps=5)
        np.testing.assert_array_equal(np.asarray(outs[r.uid].tokens),
                                      ref.tokens[0])


def test_decode_state_stays_sharded_through_admissions(dense_model):
    """Terminal decode state carries the engine's NamedShardings — lane
    grafts (B=1 prefill into the sharded batch) must not have decayed the
    layout to replicated or bounced it through the host."""
    scfg = ServingConfig(max_lanes=4, max_seq=64, max_new_tokens=4,
                         prompt_bucket=8)
    cfg, _, eng = _mesh_engine(dense_model, "dense-jnp", scfg, _mesh((4, 2)))
    eng.run(_staggered_trace(cfg, num_requests=4, max_new=4, seed=3))
    mesh = eng.mesh
    k = eng.last_state.layers.k          # (L, B, KV, S, D)
    assert k.sharding == NamedSharding(
        mesh, P(None, ("data",), "model", None, None)), k.sharding
    acc = eng.last_state.layers.acc_score
    assert acc.sharding == NamedSharding(
        mesh, P(None, ("data",), "model", None)), acc.sharding
    assert eng.last_lanes.last_token.sharding == NamedSharding(
        mesh, P(("data",))), eng.last_lanes.last_token.sharding


def test_shard_map_decode_core_matches_reference():
    """The shard_map-wrapped masked-dense core is numerically identical to
    the plain core (same einsum contractions per (lane, kv-head) shard)."""
    mesh = _mesh((4, 2))
    b, kvh, g, s, d = 8, 2, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    qq = jax.random.normal(ks[0], (b, kvh, g, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, kvh, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, kvh, s, d), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    count = jnp.full((b,), s, jnp.int32)
    ref_out, ref_w = attn_mod._masked_dense_decode_core(
        qq, k, v, positions, count, head_dim=d, window=None)
    out, w = jax.jit(lambda *a: attn_mod._shard_mapped_decode_core(
        mesh, *a, head_dim=d, window=None))(qq, k, v, positions, count)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(w), np.asarray(ref_w),
                               rtol=1e-6, atol=1e-6)


def test_block_sparse_serves_shard_mapped_without_fallback(dense_model,
                                                           caplog):
    """The Pallas block-sparse kernels are mesh citizens now: on a mesh
    whose axis extents divide (lanes over data, KV heads over model) the
    engine serves through the shard_mapped kernel path with *no* fallback
    warning — token identity vs the single-device kernel engine is
    enforced by tests/test_mesh_kernels.py."""
    cfg, params = dense_model
    cfg = dataclasses.replace(cfg, aqua=AquaConfig(k_ratio=0.75,
                                                   block_dims=8))
    proj = identity_projections(cfg.num_layers, cfg.attention.num_kv_heads,
                                cfg.attention.head_dim)
    scfg = ServingConfig(max_lanes=2, max_seq=32, max_new_tokens=3,
                         prompt_bucket=8)
    reqs = [Request(uid=i, tokens=np.arange(4 + i, dtype=np.int32),
                    arrival=float(i)) for i in range(2)]
    attn_mod.reset_mesh_fallback_warnings()
    with caplog.at_level(logging.WARNING, logger="repro.core.attention"):
        eng = ContinuousBatchingEngine(cfg, params, proj, serving=scfg,
                                       backend="aqua-block-sparse",
                                       mesh=_mesh((2, 2)))
        outs = eng.run(reqs)
    assert not any("falling back" in r.message for r in caplog.records), \
        caplog.records
    assert attn_mod.mesh_fallback_events() == ()
    assert eng.dispatch_plan().mesh_native
    assert all(len(o.tokens) == 3 for o in outs.values()), outs


def test_lane_assignment_interleaves_across_data_shards(dense_model):
    """8 lanes on a data=4 mesh: assignment preference is round-robin
    across the 4 lane shards (0,2,4,6 then 1,3,5,7), so light traffic
    spreads over the data-parallel groups."""
    cfg, params = dense_model
    scfg = ServingConfig(max_lanes=8, max_seq=32, max_new_tokens=2)
    eng = ContinuousBatchingEngine(cfg, params, None, serving=scfg,
                                   mesh=_mesh((4, 2)))
    assert eng._lane_order == [0, 2, 4, 6, 1, 3, 5, 7]
    sched = LaneScheduler(8, lane_order=eng._lane_order)
    lanes = [sched.assign(Request(uid=i, tokens=np.zeros((2,), np.int32)))
             for i in range(4)]
    assert lanes == [0, 2, 4, 6]
    with pytest.raises(AssertionError):
        LaneScheduler(4, lane_order=[0, 1, 1, 2])
