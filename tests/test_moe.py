"""MoE dispatch correctness tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs import reduced
from repro.models.moe import blocked_dispatch, init_moe_ffn, moe_ffn


def _cfg(capacity_factor=8.0, top_k=2, experts=8):
    cfg = reduced("olmoe-1b-7b")
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=capacity_factor,
                                     top_k=top_k, num_experts=experts))


def dense_reference(cfg, p, x):
    """Per-token dense evaluation of the selected experts (no capacity)."""
    m = cfg.moe
    b, s, dm = x.shape
    xf = x.reshape(-1, dm)
    gates = jax.nn.softmax(xf @ p["router"], axis=-1)
    topw, topi = jax.lax.top_k(gates, m.top_k)
    topw = topw / topw.sum(-1, keepdims=True)
    y = jnp.zeros_like(xf)
    for t in range(xf.shape[0]):
        acc = jnp.zeros((dm,))
        for j in range(m.top_k):
            e = int(topi[t, j])
            h = jax.nn.silu(xf[t] @ p["w1"][e]) * (xf[t] @ p["w3"][e])
            acc = acc + topw[t, j] * (h @ p["w2"][e])
        y = y.at[t].set(acc)
    return y.reshape(b, s, dm)


def test_moe_matches_dense_reference_when_dropless():
    cfg = _cfg(capacity_factor=16.0)
    p = init_moe_ffn(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = moe_ffn(cfg, p, x)
    if cfg.moe.num_shared:
        # strip the shared path for comparison
        from repro.models.layers import mlp
        g = jax.nn.sigmoid(x.reshape(-1, cfg.d_model) @ p["shared_gate"])
        y = y - (g * mlp(p["shared"], x.reshape(-1, cfg.d_model), "silu")
                 ).reshape(x.shape)
    ref = dense_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), top_k=st.sampled_from([1, 2, 4]))
def test_blocked_dispatch_invariants(seed, top_k):
    key = jax.random.PRNGKey(seed)
    t, g, e, cap = 2, 16, 8, 16  # dropless capacity
    gates = jax.nn.softmax(jax.random.normal(key, (t, g, e)), -1)
    dispatch, combine, aux = blocked_dispatch(gates, top_k, cap)
    d = np.asarray(dispatch, np.float32)
    c = np.asarray(combine)
    # each token dispatched exactly top_k times (dropless capacity)
    np.testing.assert_array_equal(d.sum(axis=(2, 3)), top_k)
    # combine weights sum to 1 per token (renormalized top-k)
    np.testing.assert_allclose(c.sum(axis=(2, 3)), 1.0, rtol=1e-5)
    # no buffer slot double-booked
    assert d.sum(axis=1).max() <= 1.0 + 1e-6
    assert np.isfinite(float(aux))


def test_capacity_dropping_reduces_dispatch():
    gates = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(2), (1, 32, 4)), -1)
    d_full, _, _ = blocked_dispatch(gates, 2, capacity=32)
    d_tight, _, _ = blocked_dispatch(gates, 2, capacity=2)
    assert (np.asarray(d_tight, np.float32).sum()
            < np.asarray(d_full, np.float32).sum())


def test_shared_experts_path():
    cfg = reduced("qwen2-moe-a2.7b")
    assert cfg.moe.num_shared >= 1
    from repro.models import build_model
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32)}
    logits, aux = model.forward(params, batch)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
