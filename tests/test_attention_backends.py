"""Attention backend registry: resolution/fallback policy and parametrized
equivalence of the aqua-block-sparse prefill against the masked-dense
reference across GQA group sizes, k_ratio values, and ragged lengths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime_flags as rtf
from repro.configs.base import AquaConfig, AttentionConfig
from repro.core import attention as A
from repro.core import kvcache as kv
from repro.kernels.ops import aqua_prefill, round_k_dims
from repro.kernels.ref import aqua_prefill_ref
from repro.core.aqua import chunk_topk_block_indices


def _params(acfg, d_model=32, seed=0):
    return A.init_attention_params(jax.random.PRNGKey(seed), d_model, acfg)


def _ortho_proj(kvh, d, seed=3):
    m = jax.random.normal(jax.random.PRNGKey(seed), (d, d))
    q, _ = jnp.linalg.qr(m)
    return jnp.broadcast_to(q, (kvh, d, d))


# ---------------------------------------------------------------------------
# registry / resolution policy
# ---------------------------------------------------------------------------


def test_registry_has_all_builtin_backends():
    assert set(A.available_backends()) >= {
        "dense-jnp", "flash", "aqua-masked-dense", "aqua-block-sparse"}


def test_unknown_backend_raises_with_available_list():
    with pytest.raises(KeyError, match="dense-jnp"):
        A.get_backend("does-not-exist")


def test_auto_resolution_off_tpu_prefers_jnp_references():
    assert A.resolve_backend("auto").name == "dense-jnp"
    assert A.resolve_backend("auto", aqua=AquaConfig()).name == \
        "aqua-masked-dense"
    assert A.resolve_backend("auto",
                             aqua=AquaConfig(enabled=False)).name == \
        "dense-jnp"


def test_auto_resolution_prefers_kernels_when_forced(monkeypatch):
    monkeypatch.setattr(rtf, "PALLAS_OVERRIDE", True)
    assert A.resolve_backend("auto").name == "flash"
    assert A.resolve_backend("auto", aqua=AquaConfig()).name == \
        "aqua-block-sparse"


def test_kernel_backends_fall_back_when_pallas_unavailable(monkeypatch):
    monkeypatch.setattr(rtf, "PALLAS_OVERRIDE", False)
    assert A.resolve_backend("flash").name == "dense-jnp"
    assert A.resolve_backend("aqua-block-sparse",
                             aqua=AquaConfig()).name == "aqua-masked-dense"
    assert A.resolve_backend("auto", aqua=AquaConfig()).name == \
        "aqua-masked-dense"


def test_aqua_native_backend_without_aqua_degrades_to_dense():
    assert A.resolve_backend("aqua-block-sparse", aqua=None).name == \
        "dense-jnp"


def test_prefill_runs_under_fallback(monkeypatch):
    """Explicit kernel backend + no Pallas must still produce finite output
    through the masked-dense reference."""
    monkeypatch.setattr(rtf, "PALLAS_OVERRIDE", False)
    acfg = AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16,
                           backend="aqua-block-sparse")
    p = _params(acfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, 32))
    out = A.prefill_attention(p, x, acfg, AquaConfig(block_dims=8),
                              _ortho_proj(2, 16))
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# ops-level equivalence: block-sparse kernel vs masked-dense reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("g", [1, 2, 4])            # GQA group sizes
@pytest.mark.parametrize("k_ratio", [0.5, 0.75, 1.0])
@pytest.mark.parametrize("ragged", [False, True])
def test_block_sparse_prefill_matches_masked_dense(g, k_ratio, ragged):
    b, kvh, s, d = 2, 2, 64, 32
    h = kvh * g
    ks = jax.random.split(jax.random.PRNGKey(17), 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    khat = jax.random.normal(ks[1], (b, kvh, s, d))
    v = jax.random.normal(ks[2], (b, kvh, s, d))
    lengths = jnp.full((b,), s, jnp.int32)
    if ragged:
        lengths = jnp.array([s - 19, s - 2], jnp.int32)
    q_blk = 16
    out = aqua_prefill(q, khat, v, lengths, k_ratio=k_ratio, block_dims=8,
                       q_blk=q_blk, k_blk=16)
    k_dims = round_k_dims(d, k_ratio, 8)
    bi = chunk_topk_block_indices(q, k_dims, 8, q_blk, lengths)
    ref = aqua_prefill_ref(q, khat, v, bi, lengths, 8, q_blk)
    valid = jnp.arange(s) < lengths[:, None]
    np.testing.assert_allclose(
        np.asarray(jnp.where(valid[:, None, :, None], out, 0)),
        np.asarray(jnp.where(valid[:, None, :, None], ref, 0)),
        rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# registry-level equivalence through prefill_attention / decode_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("heads,kvh", [(2, 2), (4, 2), (4, 1)])
def test_full_ratio_block_sparse_equals_standard_attention(heads, kvh):
    """k_ratio=1.0 + orthogonal P: the block-sparse path must reproduce
    exact attention (paper Lemma A.4) regardless of chunking."""
    d = 16
    acfg = AttentionConfig(num_heads=heads, num_kv_heads=kvh, head_dim=d)
    p = _params(acfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 24, 32))
    aq = AquaConfig(k_ratio=1.0, block_dims=8, prefill_q_blk=8,
                    prefill_k_blk=8)
    out_std = A.prefill_attention(p, x, acfg)
    out_bs = A.prefill_attention(
        p, x, dataclasses.replace(acfg, backend="aqua-block-sparse"), aq,
        _ortho_proj(kvh, d))
    np.testing.assert_allclose(np.asarray(out_bs), np.asarray(out_std),
                               rtol=2e-3, atol=2e-3)


def test_flash_backend_matches_dense_backend():
    acfg = AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=8)
    p = _params(acfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 24, 32))
    out_d = A.prefill_attention(
        p, x, dataclasses.replace(acfg, backend="dense-jnp"))
    out_f = A.prefill_attention(
        p, x, dataclasses.replace(acfg, backend="flash"))
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_f),
                               rtol=2e-4, atol=2e-4)


def test_ragged_lengths_through_prefill_attention():
    """Rows must be independent: row b's output on its valid prefix equals
    the output of prefilling that prefix alone."""
    acfg = AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16,
                           backend="aqua-block-sparse")
    p = _params(acfg)
    aq = AquaConfig(k_ratio=0.75, block_dims=8, prefill_q_blk=8,
                    prefill_k_blk=8)
    proj = _ortho_proj(2, 16)
    s, short = 32, 20
    x = jax.random.normal(jax.random.PRNGKey(6), (2, s, 32))
    lengths = jnp.array([short, s], jnp.int32)
    out = A.prefill_attention(p, x, acfg, aq, proj, lengths=lengths)
    out_solo = A.prefill_attention(p, x[:1, :short], acfg, aq, proj)
    np.testing.assert_allclose(np.asarray(out[0, :short]),
                               np.asarray(out_solo[0]),
                               rtol=2e-3, atol=2e-3)


def test_decode_dispatch_matches_masked_dense_reference():
    """Block-sparse decode kernel vs jnp masked-dense at block_dims=8 —
    identical selection, so outputs agree to kernel fp tolerance."""
    d = 16
    acfg = AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=d)
    p = _params(acfg)
    aq = AquaConfig(k_ratio=0.75, block_dims=8)
    proj = _ortho_proj(2, d)
    c_bs = kv.init_attn_cache(2, 2, 16, d, d, jnp.float32)
    c_md = kv.init_attn_cache(2, 2, 16, d, d, jnp.float32)
    cfg_bs = dataclasses.replace(acfg, backend="aqua-block-sparse")
    cfg_md = dataclasses.replace(acfg, backend="aqua-masked-dense")
    for t in range(5):
        xt = jax.random.normal(jax.random.PRNGKey(20 + t), (2, 32))
        o1, c_bs = A.decode_attention(p, xt, c_bs, cfg_bs, aq, proj)
        o2, c_md = A.decode_attention(p, xt, c_md, cfg_md, aq, proj)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(c_bs.k), np.asarray(c_md.k),
                               rtol=1e-6, atol=1e-6)


def test_decode_dispatch_falls_back_for_windowed_cache():
    """Sliding-window caches need per-slot position masking: the registry
    must route them to the masked-dense decode path (and still be exact)."""
    d = 16
    acfg = AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=d,
                           window=4, backend="aqua-block-sparse")
    p = _params(acfg)
    aq = AquaConfig(k_ratio=0.75, block_dims=8)
    proj = _ortho_proj(2, d)
    cache = kv.init_attn_cache(1, 2, 4, d, d, jnp.float32)
    for t in range(6):
        xt = jax.random.normal(jax.random.PRNGKey(40 + t), (1, 32))
        out, cache = A.decode_attention(p, xt, cache, acfg, aq, proj)
        assert np.isfinite(np.asarray(out)).all()
    assert int(cache.count[0]) == 6


def test_ragged_generation_equals_unpadded_generation():
    """End-to-end ragged serving: a short row in a padded batch must decode
    the same greedy tokens as prefilling its unpadded prompt alone (logits
    from the last *valid* token, cache count at the true prefix length)."""
    from repro.configs import reduced
    from repro.core.calibration import identity_projections
    from repro.serving import ServeEngine
    import numpy as np

    cfg = dataclasses.replace(reduced("qwen3-0.6b"), remat=False,
                              dtype="float32")
    cfg = dataclasses.replace(cfg, aqua=AquaConfig(k_ratio=0.75,
                                                   block_dims=8,
                                                   prefill_q_blk=8,
                                                   prefill_k_blk=8))
    from repro.models import build_model
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    proj = identity_projections(cfg.num_layers, cfg.attention.num_kv_heads,
                                cfg.attention.head_dim)
    s, short = 24, 17
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0,
                              cfg.vocab_size)
    eng = ServeEngine(cfg, params, proj, max_seq=64,
                      backend="aqua-block-sparse")
    ragged = eng.generate({"tokens": toks,
                           "lengths": jnp.array([short, s], jnp.int32)},
                          steps=5)
    solo = eng.generate({"tokens": toks[:1, :short]}, steps=5)
    np.testing.assert_array_equal(ragged.tokens[0], solo.tokens[0])


def test_chunked_path_handles_ragged_lengths(monkeypatch):
    """Long ragged prefills must flow through the chunked online-softmax
    scan (not the materialized S×S path) and still mask per-row tails."""
    import numpy as np
    monkeypatch.setattr(A, "CHUNKED_THRESHOLD", 16)
    acfg = AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=8,
                           backend="dense-jnp")
    p = _params(acfg)
    s, short = 32, 21
    x = jax.random.normal(jax.random.PRNGKey(8), (2, s, 32))
    lengths = jnp.array([short, s], jnp.int32)
    out = A.prefill_attention(p, x, acfg, lengths=lengths)   # chunked
    out_solo = A.prefill_attention(p, x[:1, :short], acfg)   # dense
    np.testing.assert_allclose(np.asarray(out[0, :short]),
                               np.asarray(out_solo[0]),
                               rtol=2e-4, atol=2e-4)


def test_block_dims1_downgrades_to_flash_at_same_numerics(monkeypatch):
    """On TPU (kernels preferred) block_dims=1 can't use the block-sparse
    kernel; it must route to masked-q flash with numerics identical to the
    masked-dense reference (masked-q identity is exact)."""
    import numpy as np
    acfg = AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16,
                           backend="aqua-block-sparse")
    p = _params(acfg)
    aq = AquaConfig(k_ratio=0.75, block_dims=1)
    proj = _ortho_proj(2, 16)
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 16, 32))
    ref = A.prefill_attention(
        p, x, dataclasses.replace(acfg, backend="aqua-masked-dense"), aq,
        proj)
    monkeypatch.setattr(rtf, "PALLAS_OVERRIDE", True)   # kernels preferred
    out = A.prefill_attention(p, x, acfg, aq, proj)     # -> masked-q flash
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_ragged_lengths_with_window_cache_raises():
    from repro.core.attention import build_cache_from_prefill
    acfg = AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=8, window=4)
    p = _params(acfg)
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 12, 32))
    with pytest.raises(ValueError, match="full-cache policy"):
        build_cache_from_prefill(p, x, acfg, None, None, max_seq=16,
                                 lengths=jnp.array([8, 12], jnp.int32))


def test_ragged_lengths_rejected_for_cross_attention_and_ssm_families():
    # cross-attention + lengths: self-attn-only semantics -> raise
    acfg = AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=8)
    p = _params(acfg)
    x = jax.random.normal(jax.random.PRNGKey(11), (1, 8, 32))
    enc = jax.random.normal(jax.random.PRNGKey(12), (1, 6, 32))
    with pytest.raises(ValueError, match="encoder-side"):
        A.prefill_attention(p, x, acfg, kv_x=enc,
                            lengths=jnp.array([4], jnp.int32))

    # non-dense families: engine rejects ragged batches up front
    from repro.configs import reduced
    from repro.models import build_model
    from repro.serving import ServeEngine
    cfg = dataclasses.replace(reduced("mamba2-370m"), remat=False,
                              dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, None, max_seq=32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    with pytest.raises(ValueError, match="rectangular"):
        eng.generate({"tokens": toks,
                      "lengths": jnp.array([4, 8], jnp.int32)}, steps=1)


def test_chunked_attention_pads_non_divisible_sequences():
    """S not divisible by the block sizes must pad+mask, not assert."""
    import numpy as np
    b, s, kvh, g, d = 1, 40, 2, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q = jax.random.normal(ks[0], (b, s, kvh, g, d))
    k = jax.random.normal(ks[1], (b, s, kvh, d))
    v = jax.random.normal(ks[2], (b, s, kvh, d))
    out = A.chunked_attention(q, k, v, head_dim=d, causal=True,
                              q_blk=16, k_blk=16)          # 40 % 16 != 0
    sc = jnp.einsum("bskgd,btkd->bkgst", q, k) / np.sqrt(d)
    mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    ref = jnp.einsum("bkgst,btkd->bskgd", jax.nn.softmax(sc, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_engine_accepts_auto_backend_override():
    from repro.configs import reduced
    from repro.models import build_model
    from repro.serving import ServeEngine
    cfg = dataclasses.replace(reduced("qwen3-0.6b"), remat=False,
                              dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, None, max_seq=32, backend="auto")
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                              cfg.vocab_size)
    r = eng.generate({"tokens": toks}, steps=2)
    assert r.tokens.shape == (1, 2)
