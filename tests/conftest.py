import os
import sys

# Tests run on CPU. The host platform is forced to 8 fake devices so the
# sharded-serving / TP/DP code paths (tests/test_sharded_serving.py) are
# exercised by every local run, exactly like the CI `multidevice` job; a
# caller-provided device-count flag wins. Single-device semantics are
# unaffected for the rest of the suite — arrays live on device 0 unless a
# test builds a mesh. (The dry-run spawns its own subprocess with 512
# host devices; see launch/dryrun.py.)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8"
    ).strip()
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
