import os
import sys

# tests run single-device on CPU; the dry-run (and only the dry-run)
# spawns its own subprocess with 512 host devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
