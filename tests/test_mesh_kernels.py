"""Kernel-under-mesh token identity (8 forced host devices, conftest.py).

The shard_map-wrapped AQUA block-sparse Pallas kernels must serve
token-identically to the single-device kernel engine at greedy —
per-(lane, head) work is independent, so the mesh wrap is bit-exact —
with no ``_log_mesh_kernel_fallback`` emission. Non-divisible axis
extents (a batch the data axes can't partition) keep the jnp reference
path: once, with the logged reason. MQA (KV=1) replicates the head axis
and stays on the kernel path (asserted via placement independence and a
bitwise wrap-vs-unwrapped check — KV=1 makes the params' TP split the
query-group axis, so cross-partitioning identity is not a property of
*any* backend there); ``NB_sel == NB_total`` (k_ratio=1.0) degenerates
to dense streaming and must agree with the masked-dense reference too.
"""
import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.configs.base import (AquaConfig, CacheSpec, QuantSpec,
                                ServingConfig)
from repro.core import attention as attn_mod
from repro.core.calibration import identity_projections
from repro.distributed import sharding as dsh
from repro.launch.mesh import make_serving_mesh
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, Request, ServeEngine

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(autouse=True)
def _fresh_fallback_dedup():
    # warning assertions must not depend on what earlier tests emitted
    attn_mod.reset_mesh_fallback_warnings()
    yield
    attn_mod.reset_mesh_fallback_warnings()


@pytest.fixture(scope="module")
def base_model():
    cfg = dataclasses.replace(reduced("qwen3-0.6b"), remat=False,
                              dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _aqua_model(base_model, k_ratio=0.5, num_kv_heads=None):
    cfg, params = base_model
    if num_kv_heads is not None:
        cfg = dataclasses.replace(
            cfg, attention=dataclasses.replace(cfg.attention,
                                               num_kv_heads=num_kv_heads))
        params = build_model(cfg).init(jax.random.PRNGKey(1))
    cfg = dataclasses.replace(cfg, aqua=AquaConfig(k_ratio=k_ratio,
                                                   block_dims=8))
    proj = identity_projections(cfg.num_layers, cfg.attention.num_kv_heads,
                                cfg.attention.head_dim)
    return cfg, params, proj


def _trace(cfg, num_requests, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        size=(int(rng.integers(4, 22)),),
                                        dtype=np.int32),
                    max_new_tokens=max_new, arrival=float(i) * 1.5)
            for i in range(num_requests)]


def _assert_identical_to_solo_kernel(cfg, params, proj, outs, reqs, steps):
    solo = ServeEngine(cfg, params, proj, max_seq=64,
                       backend="aqua-block-sparse")
    for r in reqs:
        ref = solo.generate(
            {"tokens": jnp.asarray(np.asarray(r.tokens)[None])}, steps=steps)
        np.testing.assert_array_equal(
            np.asarray(outs[r.uid].tokens), ref.tokens[0],
            err_msg=f"uid={r.uid}")


def test_kernel_mesh_token_identity(base_model):
    """2x2 data×model mesh, staggered traffic: the shard_mapped kernel
    engine is token-identical to the single-device kernel engine at
    greedy, and never falls back."""
    cfg, params, proj = _aqua_model(base_model, k_ratio=0.5)
    scfg = ServingConfig(max_lanes=4, max_seq=64, max_new_tokens=6,
                         prompt_bucket=8)
    eng = ContinuousBatchingEngine(cfg, params, proj, serving=scfg,
                                   backend="aqua-block-sparse",
                                   mesh=make_serving_mesh((2, 2)))
    assert eng.dispatch_plan().mesh_native
    reqs = _trace(cfg, num_requests=4, max_new=6)
    outs = eng.run(reqs)
    assert eng.mesh_fallback_events() == ()
    assert attn_mod.mesh_fallback_events() == ()   # process aggregate too
    _assert_identical_to_solo_kernel(cfg, params, proj, outs, reqs, 6)
    # kernel-native layout: lanes over data, KV heads over model, slot
    # axis and dim-blocks whole per shard
    k = eng.last_state.layers.k
    assert k.sharding.spec == jax.sharding.PartitionSpec(
        None, ("data",), "model", None, None), k.sharding


def test_full_ratio_matches_kernel_and_reference(base_model):
    """NB_sel == NB_total (k_ratio=1.0): selection degenerates to dense
    streaming, so mesh kernel == solo kernel == masked-dense reference
    tokens at greedy."""
    cfg, params, proj = _aqua_model(base_model, k_ratio=1.0)
    scfg = ServingConfig(max_lanes=4, max_seq=64, max_new_tokens=5,
                         prompt_bucket=8)
    reqs = _trace(cfg, num_requests=3, max_new=5, seed=2)
    eng = ContinuousBatchingEngine(cfg, params, proj, serving=scfg,
                                   backend="aqua-block-sparse",
                                   mesh=make_serving_mesh((2, 2)))
    outs = eng.run(reqs)
    assert eng.mesh_fallback_events() == ()
    _assert_identical_to_solo_kernel(cfg, params, proj, outs, reqs, 5)
    ref = ServeEngine(cfg, params, proj, max_seq=64,
                      backend="aqua-masked-dense")
    for r in reqs:
        expect = ref.generate(
            {"tokens": jnp.asarray(np.asarray(r.tokens)[None])}, steps=5)
        np.testing.assert_array_equal(np.asarray(outs[r.uid].tokens),
                                      expect.tokens[0])


def test_mqa_kernel_under_mesh(base_model):
    """MQA (KV=1): the single KV head can't split over `model`, so the
    head axis replicates while lanes still partition over `data` — the
    kernel path must serve (not fall back) with the kernel-native cache
    layout, and sampling must be placement-independent on the mesh.

    (Cross-*partitioning* token identity is not asserted for MQA: with
    KV=1 the params' TP falls back to splitting the query-group axis,
    which reorders the output-projection float reduction vs a single
    device — a pre-existing property of every backend under TP, not of
    the kernel wrap. The wrap itself is pinned bitwise by
    test_shard_mapped_kernel_wrap_is_bitwise below.)"""
    cfg, params, proj = _aqua_model(base_model, k_ratio=0.5, num_kv_heads=1)
    mesh = make_serving_mesh((2, 2))
    assert dsh.kernel_shardable(mesh, cfg.attention, cfg.aqua, batch=4)
    scfg = ServingConfig(max_lanes=4, max_seq=64, max_new_tokens=4,
                         prompt_bucket=8)
    reqs = _trace(cfg, num_requests=3, max_new=4, seed=3)
    eng = ContinuousBatchingEngine(cfg, params, proj, serving=scfg,
                                   backend="aqua-block-sparse", mesh=mesh)
    assert eng.dispatch_plan().mesh_native
    outs = eng.run(reqs)
    assert eng.mesh_fallback_events() == ()
    # placement independence at greedy: each request re-served solo on a
    # fresh engine over the SAME mesh yields the same tokens regardless
    # of lane placement / co-tenants
    for r in reqs:
        solo = ContinuousBatchingEngine(cfg, params, proj, serving=scfg,
                                        backend="aqua-block-sparse",
                                        mesh=mesh)
        ref = solo.run([dataclasses.replace(r, arrival=0.0)])
        np.testing.assert_array_equal(outs[r.uid].tokens,
                                      ref[r.uid].tokens,
                                      err_msg=f"uid={r.uid}")
    # kernel-native MQA layout: head axis replicated, slot axis NOT
    # absorbed into `model` (the kernel streams whole sequence stripes)
    k = eng.last_state.layers.k
    assert k.sharding.spec == jax.sharding.PartitionSpec(
        None, ("data",), None, None, None), k.sharding


@pytest.mark.parametrize("kvh", [1, 2])
def test_shard_mapped_kernel_wrap_is_bitwise(kvh):
    """The shard_map wrap around the block-sparse kernels is bit-exact vs
    the unwrapped kernel call on identical inputs — per-(lane, head) work
    is independent and the per-shard block-index tables equal the global
    ones. Covers GQA (KV heads split over `model`) and MQA (head axis
    replicated)."""
    from repro.configs.base import AttentionConfig
    from repro.core import kvcache as kvc

    mesh = make_serving_mesh((2, 2))
    b, g, s, d = 4, 2, 32, 16
    h = kvh * g
    cfg = AttentionConfig(num_heads=h, num_kv_heads=kvh, head_dim=d)
    aqua = AquaConfig(k_ratio=0.5, block_dims=8)
    backend = attn_mod.get_backend("aqua-block-sparse")
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    qp = jax.random.normal(ks[0], (b, s, kvh, g, d), jnp.float32)
    kp = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
    vp = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)
    positions = jnp.arange(s, dtype=jnp.int32)
    lengths = jnp.full((b,), s, jnp.int32)

    ref, _ = backend.prefill(qp, kp, vp, cfg=cfg, aqua=aqua,
                             positions=positions, lengths=lengths,
                             causal=True)
    out, _ = jax.jit(lambda *a: attn_mod.shard_mapped_prefill_kernel(
        mesh, backend, *a, cfg=cfg, aqua=aqua, positions=positions,
        lengths=lengths, causal=True))(qp, kp, vp)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    qd = jax.random.normal(ks[3], (b, kvh, g, d), jnp.float32)
    cache = kvc.AttnCache(
        k=kp.transpose(0, 2, 1, 3), v=vp.transpose(0, 2, 1, 3),
        positions=jnp.broadcast_to(positions, (b, s)),
        count=jnp.full((b,), s, jnp.int32),
        acc_score=jnp.zeros((b, kvh, s), jnp.float32))
    ref_d = backend.decode(qd, cache, cfg=cfg, aqua=aqua)
    out_d = jax.jit(lambda q, c: attn_mod.shard_mapped_decode_kernel(
        mesh, backend, q, c, cfg=cfg, aqua=aqua))(qd, cache)
    np.testing.assert_array_equal(np.asarray(out_d), np.asarray(ref_d))


PAGED_SCFG = ServingConfig(max_lanes=4, max_seq=64, max_new_tokens=6,
                           prompt_bucket=8,
                           cache=CacheSpec(page_size=8, num_pages=32))


def test_paged_kernel_mesh_token_identity(base_model):
    """The tentpole contract: paged + mesh decodes through the
    shard_mapped paged kernel (lane-partitioned page tables, lane-global
    KV-sharded pool) and is greedy-token-identical to BOTH the contiguous
    mesh kernel engine and the single-device paged engine."""
    cfg, params, proj = _aqua_model(base_model, k_ratio=0.5)
    mesh = make_serving_mesh((2, 2))
    reqs = _trace(cfg, num_requests=4, max_new=6, seed=5)

    eng = ContinuousBatchingEngine(cfg, params, proj, serving=PAGED_SCFG,
                                   backend="aqua-block-sparse", mesh=mesh)
    plan = eng.dispatch_plan()
    assert plan.mesh_native and plan.paged, plan
    outs = eng.run([dataclasses.replace(r) for r in reqs])
    assert eng.mesh_fallback_events() == ()
    assert attn_mod.mesh_fallback_events() == ()

    cscfg = dataclasses.replace(PAGED_SCFG, cache=CacheSpec())
    contig = ContinuousBatchingEngine(cfg, params, proj, serving=cscfg,
                                      backend="aqua-block-sparse", mesh=mesh)
    assert contig.dispatch_plan().mesh_native
    c_outs = contig.run([dataclasses.replace(r) for r in reqs])

    solo = ContinuousBatchingEngine(cfg, params, proj, serving=PAGED_SCFG,
                                    backend="aqua-block-sparse")
    s_outs = solo.run([dataclasses.replace(r) for r in reqs])
    for r in reqs:
        np.testing.assert_array_equal(np.asarray(outs[r.uid].tokens),
                                      np.asarray(c_outs[r.uid].tokens),
                                      err_msg=f"vs contiguous+mesh "
                                              f"uid={r.uid}")
        np.testing.assert_array_equal(np.asarray(outs[r.uid].tokens),
                                      np.asarray(s_outs[r.uid].tokens),
                                      err_msg=f"vs paged solo uid={r.uid}")
    # pool sharding: pages lane-global (never data-sharded), KV heads over
    # model; page-table rows ride the lane axis
    kp = eng.last_state.layers.k_pool
    assert kp.sharding.spec == jax.sharding.PartitionSpec(
        None, None, "model", None, None), kp.sharding
    pt = eng.last_state.layers.page_table
    assert pt.sharding.spec == jax.sharding.PartitionSpec(
        None, ("data",), None), pt.sharding


def test_prefix_shared_lanes_decode_through_kernel(base_model):
    """Prefix-shared admissions (same page-aligned prompt prefix mapping
    the same physical pages) still decode through the shard_mapped paged
    kernel — shared pages are pool-global ids like any other table entry
    — token-identically to the solo paged engine."""
    cfg, params, proj = _aqua_model(base_model, k_ratio=0.5)
    mesh = make_serving_mesh((2, 2))
    rng = np.random.default_rng(6)
    pre = rng.integers(0, cfg.vocab_size, size=(8,), dtype=np.int32)
    reqs = [Request(uid=i,
                    tokens=np.concatenate(
                        [pre, rng.integers(0, cfg.vocab_size, size=(4 + i,),
                                           dtype=np.int32)]),
                    max_new_tokens=5, arrival=float(i) * 1.5)
            for i in range(4)]
    eng = ContinuousBatchingEngine(cfg, params, proj, serving=PAGED_SCFG,
                                   backend="aqua-block-sparse", mesh=mesh)
    plan = eng.dispatch_plan()
    assert plan.mesh_native and plan.prefix_sharing, plan
    outs = eng.run([dataclasses.replace(r) for r in reqs])
    assert eng.mesh_fallback_events() == ()
    assert eng.page_pool.prefix_hits >= 1, eng.page_pool
    solo = ContinuousBatchingEngine(cfg, params, proj, serving=PAGED_SCFG,
                                    backend="aqua-block-sparse")
    s_outs = solo.run([dataclasses.replace(r) for r in reqs])
    for r in reqs:
        np.testing.assert_array_equal(np.asarray(outs[r.uid].tokens),
                                      np.asarray(s_outs[r.uid].tokens),
                                      err_msg=f"uid={r.uid}")


@pytest.mark.parametrize("kvh", [1, 2])
def test_shard_mapped_paged_kernel_wrap_is_bitwise(kvh):
    """The shard_map wrap around the *paged* decode kernel is bit-exact vs
    the unwrapped kernel on an identical pool: page-table rows partition
    with their lanes, the pool's page axis stays whole per data shard, and
    the pool-global page ids dereference unchanged in the index_map."""
    from repro.configs.base import AttentionConfig
    from repro.core import kvcache as kvc

    mesh = make_serving_mesh((2, 2))
    b, g, d, ps, ppl = 4, 2, 16, 8, 4
    s = ps * ppl
    h = kvh * g
    num_pages = b * ppl
    cfg = AttentionConfig(num_heads=h, num_kv_heads=kvh, head_dim=d)
    aqua = AquaConfig(k_ratio=0.5, block_dims=8)
    backend = attn_mod.get_backend("aqua-block-sparse")
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    cache = kvc.PagedAttnCache(
        k_pool=jax.random.normal(ks[0], (num_pages, kvh, ps, d),
                                 jnp.float32),
        v_pool=jax.random.normal(ks[1], (num_pages, kvh, ps, d),
                                 jnp.float32),
        pos_pool=jnp.tile(jnp.arange(ps, dtype=jnp.int32)[None],
                          (num_pages, 1))
        + ps * jnp.tile(jnp.arange(ppl, dtype=jnp.int32), b)[:, None],
        acc_pool=jnp.zeros((num_pages, kvh, ps), jnp.float32),
        page_table=jnp.arange(num_pages,
                              dtype=jnp.int32).reshape(b, ppl),
        count=jnp.full((b,), s, jnp.int32))
    qd = jax.random.normal(ks[2], (b, kvh, g, d), jnp.float32)
    ref = backend.paged_decode(qd, cache, cfg=cfg, aqua=aqua)
    out = jax.jit(lambda q, c: attn_mod.shard_mapped_paged_decode_kernel(
        mesh, backend, q, c, cfg=cfg, aqua=aqua))(qd, cache)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_paged_nondivisible_batch_routes_to_jnp_once(base_model, caplog):
    """max_lanes=3 paged on a data=2 mesh: the page-table rows can't
    partition the data axes, so paged decode routes to the jnp reference
    on the gathered lane view — once, with the logged reason — and the
    plan predicts it with the same reason string."""
    from repro.core.dispatch import REASON_NONDIVISIBLE_MESH

    cfg, params, proj = _aqua_model(base_model, k_ratio=0.5)
    scfg = dataclasses.replace(PAGED_SCFG, max_lanes=3, max_new_tokens=4,
                               cache=CacheSpec(page_size=8, num_pages=24))
    reqs = _trace(cfg, num_requests=3, max_new=4, seed=8)
    with caplog.at_level(logging.WARNING, logger="repro.core.attention"):
        eng = ContinuousBatchingEngine(cfg, params, proj, serving=scfg,
                                       backend="aqua-block-sparse",
                                       mesh=make_serving_mesh((2, 2)))
        plan = eng.dispatch_plan()
        assert not plan.mesh_native
        assert plan.reasons == (REASON_NONDIVISIBLE_MESH,), plan
        outs = eng.run(reqs)
    warns = [r for r in caplog.records if "falling back" in r.message]
    assert len(warns) == 1, caplog.records
    assert "decode" in warns[0].message and "aqua-block-sparse" \
        in warns[0].message
    events = eng.mesh_fallback_events()
    assert [e[1] for e in events] == ["decode"], events
    assert events[0][2] == REASON_NONDIVISIBLE_MESH, events
    assert all(len(o.tokens) == 4 for o in outs.values()), outs


def test_paged_page_geometry_routes_to_jnp_with_reason(base_model, caplog):
    """page_size=4 can't tile into the kernel's 8-token sequence blocks:
    the plan (and the logged trace-time fallback) carry the page-geometry
    reason, distinct from the axis-divisibility one."""
    from repro.core.dispatch import REASON_PAGE_GEOMETRY

    cfg, params, proj = _aqua_model(base_model, k_ratio=0.5)
    scfg = dataclasses.replace(PAGED_SCFG, max_new_tokens=3,
                               cache=CacheSpec(page_size=4, num_pages=64))
    reqs = _trace(cfg, num_requests=2, max_new=3, seed=9)
    with caplog.at_level(logging.WARNING, logger="repro.core.attention"):
        eng = ContinuousBatchingEngine(cfg, params, proj, serving=scfg,
                                       backend="aqua-block-sparse",
                                       mesh=make_serving_mesh((2, 2)))
        plan = eng.dispatch_plan()
        assert not plan.mesh_native
        assert plan.reasons == (REASON_PAGE_GEOMETRY,), plan
        outs = eng.run(reqs)
    events = eng.mesh_fallback_events()
    assert [e[1] for e in events] == ["decode"], events
    assert events[0][2] == REASON_PAGE_GEOMETRY, events
    assert all(len(o.tokens) == 3 for o in outs.values()), outs


def test_nondivisible_batch_routes_to_jnp_once(base_model, caplog):
    """max_lanes=3 on a data=2 mesh: the decode batch can't partition the
    data axes (the cache's slot axis absorbed them), so decode routes to
    the shard_map/jnp reference — once, with the logged reason — while
    the B=1 admission prefills still run the shard_mapped kernel."""
    cfg, params, proj = _aqua_model(base_model, k_ratio=0.5)
    scfg = ServingConfig(max_lanes=3, max_seq=64, max_new_tokens=4,
                         prompt_bucket=8)
    reqs = _trace(cfg, num_requests=3, max_new=4, seed=4)
    with caplog.at_level(logging.WARNING, logger="repro.core.attention"):
        eng = ContinuousBatchingEngine(cfg, params, proj, serving=scfg,
                                       backend="aqua-block-sparse",
                                       mesh=make_serving_mesh((2, 2)))
        outs = eng.run(reqs)
    assert not eng.dispatch_plan().mesh_native
    warns = [r for r in caplog.records if "falling back" in r.message]
    assert len(warns) == 1, caplog.records
    assert "decode" in warns[0].message and "aqua-block-sparse" \
        in warns[0].message
    events = eng.mesh_fallback_events()
    assert [e[1] for e in events] == ["decode"], events
    assert all(len(o.tokens) == 4 for o in outs.values()), outs