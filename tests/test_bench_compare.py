"""Unit tests for the bench-regression gate (benchmarks/compare.py).

The normalized tok/s gate divides each serving row by the same file's
rectangular-serialized anchor so machine speed cancels. When the anchor
row is absent from either file the gate must be *skipped with a loud
stderr note* — not silently fall back to absolute tok/s, which compares
across machine speeds and fails (or passes) spuriously.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import compare as cmp  # noqa: E402

ANCHOR = cmp.RECTANGULAR


def _table(rows):
    return {name: (derived, cmp._metrics(derived)) for name, derived in rows}


def _run(base, fresh, threshold=0.2):
    return list(cmp.compare(base, fresh, threshold))


def test_normalized_gate_with_anchor_on_both_sides():
    base = _table(
        [
            ("serving/dense-jnp", "tok_s=100.0 occupancy=2.00"),
            (ANCHOR, "tok_s=50.0 occupancy=1.00"),
        ]
    )
    fresh = _table(
        [
            ("serving/dense-jnp", "tok_s=120.0 occupancy=2.00"),
            (ANCHOR, "tok_s=100.0 occupancy=1.00"),
        ]
    )
    rows = {(n, m): ok for n, m, _, _, ok in _run(base, fresh)}
    # normalized: base 2.0x anchor, fresh 1.2x anchor -> 40% drop, fails
    assert rows[("serving/dense-jnp", "tok_s_rel")] is False
    assert rows[("serving/dense-jnp", "occupancy")] is True


def test_missing_anchor_skips_normalized_gate(capsys):
    """Anchor absent from the baseline: the row's tok/s must not be
    judged at all (the baseline value would be absolute, the fresh one
    normalized), and a stderr note must say so."""
    base = _table([("serving/dense-jnp", "tok_s=100.0 occupancy=2.00")])
    fresh = _table(
        [
            ("serving/dense-jnp", "tok_s=1.0 occupancy=2.00"),
            (ANCHOR, "tok_s=50.0 occupancy=1.00"),
        ]
    )
    judged = _run(base, fresh)
    names = [(n, m) for n, m, _, _, _ in judged]
    assert names == [("serving/dense-jnp", "occupancy")]
    err = capsys.readouterr().err
    assert "anchor" in err and "serving/dense-jnp" in err
    assert "baseline" in err


def test_missing_anchor_in_fresh_run_notes_and_flags_row(capsys):
    """Anchor present in the baseline but missing from the fresh run: the
    anchor row itself fails the presence check (the canonical row set is
    part of the contract), while the serving row's tok/s gate is skipped
    with a note instead of comparing normalized-vs-absolute."""
    base = _table(
        [
            ("serving/dense-jnp", "tok_s=100.0 occupancy=2.00"),
            (ANCHOR, "tok_s=50.0 occupancy=1.00"),
        ]
    )
    fresh = _table([("serving/dense-jnp", "tok_s=90.0 occupancy=2.00")])
    judged = _run(base, fresh)
    present = [(n, ok) for n, m, _, _, ok in judged if m == "present"]
    assert present == [(ANCHOR, False)]
    names = [(n, m) for n, m, _, _, _ in judged]
    assert ("serving/dense-jnp", "tok_s_rel") not in names
    err = capsys.readouterr().err
    assert "anchor" in err and "fresh run" in err


def test_anchor_present_rows_still_gate_deterministic_metrics():
    base = _table([("kernel/aqua_decode_k0.5", "hbm_bytes_ratio=0.600")])
    fresh = _table([("kernel/aqua_decode_k0.5", "hbm_bytes_ratio=0.900")])
    rows = {(n, m): ok for n, m, _, _, ok in _run(base, fresh)}
    assert rows[("kernel/aqua_decode_k0.5", "hbm_bytes_ratio")] is False


def test_ppl_gate_bounds_upward_drift():
    """Perplexity gates one-sided: fresh <= base * (1 + threshold).
    Getting *better* (lower) never fails; drifting above the band does."""
    base = _table([("quality/hf_ppl_k0.5", "ppl=100.0")])
    ok_fresh = _table([("quality/hf_ppl_k0.5", "ppl=115.0")])
    rows = {(n, m): ok for n, m, _, _, ok in _run(base, ok_fresh)}
    assert rows[("quality/hf_ppl_k0.5", "ppl")] is True
    bad_fresh = _table([("quality/hf_ppl_k0.5", "ppl=125.0")])
    rows = {(n, m): ok for n, m, _, _, ok in _run(base, bad_fresh)}
    assert rows[("quality/hf_ppl_k0.5", "ppl")] is False
    better = _table([("quality/hf_ppl_k0.5", "ppl=10.0")])
    rows = {(n, m): ok for n, m, _, _, ok in _run(base, better)}
    assert rows[("quality/hf_ppl_k0.5", "ppl")] is True


def test_ppl_gate_threshold_scales():
    base = _table([("quality/aqua_k0.5", "ppl=2.0")])
    fresh = _table([("quality/aqua_k0.5", "ppl=2.5")])
    rows = {(n, m): ok for n, m, _, _, ok in _run(base, fresh, 0.2)}
    assert rows[("quality/aqua_k0.5", "ppl")] is False
    rows = {(n, m): ok for n, m, _, _, ok in _run(base, fresh, 0.5)}
    assert rows[("quality/aqua_k0.5", "ppl")] is True


def test_acc_and_token_match_gate_absolute_drift():
    base = _table(
        [("quality/aqua_k0.5", "ppl=2.0 acc=0.90 token_match=0.95")])
    fresh = _table(
        [("quality/aqua_k0.5", "ppl=2.0 acc=0.86 token_match=0.89")])
    rows = {(n, m): ok for n, m, _, _, ok in _run(base, fresh)}
    assert rows[("quality/aqua_k0.5", "acc")] is True      # within 0.05
    assert rows[("quality/aqua_k0.5", "token_match")] is False


def test_skipped_quality_row_fails_presence_gate():
    """A baseline quality row that comes back as a skipped sentinel (e.g.
    the bench ran without enough devices) must fail, exactly like the
    mesh serving rows — the canonical row set is part of the contract."""
    base = _table([("quality/hf_match_k0.5@mesh2x2", "token_match=0.9")])
    fresh = _table([("quality/hf_match_k0.5@mesh2x2", "skipped=devices<4 (1)")])
    rows = {(n, m): ok for n, m, _, _, ok in _run(base, fresh)}
    assert rows[("quality/hf_match_k0.5@mesh2x2", "present")] is False


def test_exit_summary_names_each_failed_gate(tmp_path, capsys):
    """A red gate's exit summary must name WHICH row+metric failed — a
    bare failure count forces re-scrolling the whole table in CI logs."""
    base = [
        {"name": "kernel/aqua_decode_k0.5", "us_per_call": 1.0,
         "derived": "hbm_bytes_ratio=0.600 max_abs_err=1e-6"},
        {"name": "kernel/healthy", "us_per_call": 1.0,
         "derived": "max_abs_err=1e-6"},
    ]
    fresh = [
        {"name": "kernel/aqua_decode_k0.5", "us_per_call": 1.0,
         "derived": "hbm_bytes_ratio=0.900 max_abs_err=1e-6"},
        {"name": "kernel/healthy", "us_per_call": 1.0,
         "derived": "max_abs_err=1e-6"},
    ]
    bp, fp = tmp_path / "base.json", tmp_path / "fresh.json"
    bp.write_text(json.dumps(base))
    fp.write_text(json.dumps(fresh))
    with pytest.raises(SystemExit) as exc:
        cmp.main([str(bp), str(fp)])
    assert exc.value.code == 1
    out = capsys.readouterr().out
    # the summary names the failed row AND its metric, with both values
    assert "FAILED kernel/aqua_decode_k0.5: hbm_bytes_ratio" in out
    assert "base=0.6" in out and "fresh=0.9" in out
    # healthy rows stay out of the exit summary
    assert "FAILED kernel/healthy" not in out
    assert "1/3 checks beyond threshold" in out


def test_exit_summary_green_path_exits_zero(tmp_path, capsys):
    rows = [{"name": "kernel/healthy", "us_per_call": 1.0,
             "derived": "max_abs_err=1e-6"}]
    bp, fp = tmp_path / "base.json", tmp_path / "fresh.json"
    bp.write_text(json.dumps(rows))
    fp.write_text(json.dumps(rows))
    cmp.main([str(bp), str(fp)])  # must not raise SystemExit
    out = capsys.readouterr().out
    assert "bench gate green" in out
    assert "FAILED" not in out


def test_interleave_gate_compares_within_fresh_dump():
    """The chunked-prefill row must beat monolithic on p99 ITL and
    SLO-miss *within the fresh file* (machine speed cancels) while
    holding throughput within the threshold."""
    mono = "tok_s=1000.0 p50_itl_ms=1.00 p99_itl_ms=8.00 slo_miss=0.100"
    base = _table(
        [
            ("serving/interleave-monolithic", mono),
            ("serving/interleave-chunked",
             "tok_s=950.0 p50_itl_ms=0.90 p99_itl_ms=3.00 slo_miss=0.000"),
        ]
    )
    good = _table(
        [
            ("serving/interleave-monolithic", mono),
            ("serving/interleave-chunked",
             "tok_s=900.0 p50_itl_ms=0.90 p99_itl_ms=4.00 slo_miss=0.050"),
        ]
    )
    rows = {(n, m): ok for n, m, _, _, ok in _run(base, good)}
    assert rows[("serving/interleave-chunked", "p99_itl_vs_mono")] is True
    assert rows[("serving/interleave-chunked", "slo_miss_vs_mono")] is True
    assert rows[("serving/interleave-chunked", "tok_s_vs_mono")] is True
    # a chunked row whose tail latency regressed past monolithic fails
    bad = _table(
        [
            ("serving/interleave-monolithic", mono),
            ("serving/interleave-chunked",
             "tok_s=700.0 p50_itl_ms=0.90 p99_itl_ms=9.00 slo_miss=0.200"),
        ]
    )
    rows = {(n, m): ok for n, m, _, _, ok in _run(base, bad)}
    assert rows[("serving/interleave-chunked", "p99_itl_vs_mono")] is False
    assert rows[("serving/interleave-chunked", "slo_miss_vs_mono")] is False
    assert rows[("serving/interleave-chunked", "tok_s_vs_mono")] is False