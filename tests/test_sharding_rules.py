"""Unit tests for the sharding rules (divisibility fallbacks) using an
AbstractMesh (no devices needed)."""
import jax
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.distributed import sharding as sh


def _abstract_mesh(*axes):
    """AbstractMesh across JAX signature changes: ((name, size), ...) on
    0.4.3x, (axis_sizes, axis_names) on newer releases."""
    try:
        return AbstractMesh(tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(s for _, s in axes),
                            tuple(n for n, _ in axes))


MESH = _abstract_mesh(("data", 16), ("model", 16))
POD = _abstract_mesh(("pod", 2), ("data", 16), ("model", 16))


def _key(name):
    return (jax.tree_util.DictKey(name),)


def test_wq_shards_kv_heads_when_divisible():
    # stacked (L, dm, KV=16, G, D)
    spec = sh.param_pspec(_key("wq"), (24, 2048, 16, 1, 128), MESH)
    assert spec == P(None, None, "model", None, None)


def test_wq_falls_back_to_groups_for_gqa8():
    # KV=8 doesn't divide 16 -> try G=4 (fails) -> replicated
    spec = sh.param_pspec(_key("wq"), (24, 2560, 8, 4, 80), MESH)
    assert spec == P(None, None, None, None, None)


def test_wq_mqa_uses_group_axis():
    # MQA: KV=1, G=16 -> shard groups
    spec = sh.param_pspec(_key("wq"), (38, 4096, 1, 16, 256), MESH)
    assert spec == P(None, None, None, "model", None)


def test_wk_falls_back_to_head_dim():
    spec = sh.param_pspec(_key("wk"), (38, 4096, 1, 256), MESH)
    assert spec == P(None, None, None, "model")


def test_moe_expert_parallel_when_divisible():
    spec = sh.param_pspec(_key("w1"), (16, 64, 2048, 1024), MESH)
    assert spec == P(None, "model", None, None)


def test_moe_falls_back_to_ff_tp_for_60_experts():
    spec = sh.param_pspec(_key("w1"), (24, 60, 2048, 1408), MESH)
    assert spec == P(None, None, None, "model")


def test_embedding_vocab_sharding_and_fallback():
    assert sh.param_pspec(_key("table"), (151936, 1024), MESH) == \
        P("model", None)
    # 50280 % 16 != 0 -> shard d_model instead
    assert sh.param_pspec(_key("table"), (50280, 1024), MESH) == \
        P(None, "model")
    # 51865 odd and 384 % 16 == 0 -> d_model
    assert sh.param_pspec(_key("table"), (51865, 384), MESH) == \
        P(None, "model")


def test_norms_replicated():
    assert sh.param_pspec(_key("ln1"), (24, 2048), MESH) == P(None, None)
    assert sh.param_pspec(_key("lam"), (4096,), MESH) == P(None)


def test_one_dim_param_never_crashes():
    # regression: eager candidate construction crashed on 1-D params
    assert sh.param_pspec(_key("conv_b"), (4096,), MESH) == P("model")


def test_sanitize_drops_nondivisible():
    assert sh.sanitize(P("model", None), (60, 4), MESH) == P(None, None)
    assert sh.sanitize(P(("pod", "data"), None), (64, 4), POD) == \
        P(("pod", "data"), None)
    assert sh.sanitize(P(("pod", "data"), None), (16, 4), POD) == \
        P(None, None)


def test_sanitize_batch1_single_kv_serving_cache():
    """Serving cache shapes at the degenerate corners: a single decode
    lane (B=1) and MQA (KV=1) must drop every non-dividing axis
    independently — never crash, never leave a stale axis behind."""
    serving = _abstract_mesh(("data", 4), ("model", 2))
    # k (L, B, KV, S, D) with B=1 and KV=1: batch/kv axes drop, slots=64
    # absorb data×model (8 | 64)
    spec = sh.decode_state_pspec(_key("k"), (2, 1, 1, 64, 8), serving,
                                 kv_shardable=False, batch_shardable=False)
    assert spec == P(None, None, None, ("data", "model"), None)
    # tiny slot count (4 < 8): nothing divides -> fully replicated
    spec = sh.decode_state_pspec(_key("k"), (2, 1, 1, 4, 8), serving,
                                 kv_shardable=False, batch_shardable=False)
    assert spec == P(None, None, None, None, None)
    # H2O acc_score (L, B, KV, S) at B=1/KV=1 follows the same fallback
    spec = sh.decode_state_pspec(_key("acc_score"), (2, 1, 1, 64), serving,
                                 kv_shardable=False, batch_shardable=False)
    assert spec == P(None, None, None, ("data", "model"))
    # sanitize itself: every entry of a (1, 1) shape drops
    assert sh.sanitize(P(("data", "model"), "model"), (1, 1), serving) == \
        P(None, None)


def test_lane_pspec_divisibility():
    serving = _abstract_mesh(("data", 4), ("model", 2))
    assert sh.lane_pspec(serving, 8) == P(("data",))
    assert sh.lane_pspec(serving, 1) == P(None)    # single lane: replicate
    assert sh.lane_pspec(serving, 6) == P(None)    # 4 does not divide 6
    modelonly = _abstract_mesh(("model", 2))
    assert sh.lane_pspec(modelonly, 8) == P(None)  # no data axes at all


def test_batch_pspec_multi_pod():
    assert sh.batch_pspec(POD, (256, 4096)) == P(("pod", "data"), None)
    # B=16: can't use pod*data=32 -> falls back to data only
    assert sh.batch_pspec(POD, (16, 4096)) == P("data", None)
    # B=1 (long_500k): replicated
    assert sh.batch_pspec(POD, (1, 1)) == P(None, None)


def test_decode_state_kv_fallback_to_slots():
    # stacked cache (L, B, KV=8, S, D): KV not divisible -> slots on model
    spec = sh.decode_state_pspec(_key("k"), (24, 128, 8, 32768, 64), MESH,
                                 kv_shardable=False, batch_shardable=True)
    assert spec == P(None, ("data",), None, "model", None)


def test_decode_state_long_context_batch1():
    spec = sh.decode_state_pspec(_key("k"), (24, 1, 8, 4096, 64), MESH,
                                 kv_shardable=False, batch_shardable=False)
    assert spec == P(None, None, None, ("data", "model"), None)


def test_decode_state_kv_shardable():
    spec = sh.decode_state_pspec(_key("k"), (16, 128, 16, 32768, 128), MESH,
                                 kv_shardable=True, batch_shardable=True)
    assert spec == P(None, ("data",), "model", None, None)


def test_ssm_state_spec():
    spec = sh.decode_state_pspec(_key("state"), (48, 128, 32, 64, 128), MESH,
                                 kv_shardable=False, batch_shardable=True)
    assert spec == P(None, ("data",), "model", None, None)


def test_dryrun_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
      %ag = f32[16,128]{1,0} all-gather(f32[1,128] %x), dim=0
      %ar = bf16[1024]{0} all-reduce(bf16[1024] %y), to_apply=%add
      %ars = f32[8,8]{1,0} all-reduce-start(f32[8,8] %z), to_apply=%add
      %cp = u8[64]{0} collective-permute(u8[64] %w)
      %a2a = f32[4,4]{1,0} all-to-all(f32[4,4] %v)
    """
    cb = collective_bytes(hlo)
    assert cb["all-gather"] == 16 * 128 * 4
    assert cb["all-reduce"] == 1024 * 2 + 8 * 8 * 4
    assert cb["collective-permute"] == 64
    assert cb["all-to-all"] == 64


def test_input_specs_cover_all_cells():
    from repro.configs import ASSIGNED_ARCHS, SHAPES_BY_NAME, get_config
    from repro.launch.dryrun import input_specs
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for sname, shp in SHAPES_BY_NAME.items():
            if sname == "long_500k" and cfg.skip_long_context:
                continue
            specs = input_specs(cfg, shp)
            assert "params" in specs
            if shp.mode == "decode":
                assert specs["tokens"].shape == (shp.global_batch,)
            else:
                assert specs["batch"]["tokens"].shape == (
                    shp.global_batch, shp.seq_len)
