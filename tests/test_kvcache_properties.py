"""Property-based cache-invariant suite for the block-paged KV cache.

Random interleavings of insert / evict / reset / admit / retire across the
full, sliding-window, H2O, AQUA-Memory-sliced and paged policies must
preserve the paging invariants:

  * no two lanes map the same physical page unless it is a registered
    shared-prefix page (refcounted),
  * ``refcount[p]`` equals the number of lanes mapping page ``p``,
  * freed pages are never referenced by any lane,
  * ``positions`` stay consistent with ``count`` (every valid position is
    < count; the gathered paged view equals the contiguous layout
    slot-for-slot),
  * paged decode attention is token/output-identical to the contiguous
    cache (full + window policies; page-granular H2O matches its own
    numpy oracle instead — whole-page eviction is a deliberate policy
    divergence).

Runs under the ``_hypothesis_compat`` shim: with hypothesis installed the
strategies explore; on a bare install a deterministic fallback set keeps
every property executing real assertions.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import kvcache as kv
from repro.core.h2o import reference_victim_page
from repro.serving.scheduler import PagePool

DK = DV = 8
KV_HEADS = 2


def _rand_kv(rng, batch):
    k = jnp.asarray(rng.normal(size=(batch, KV_HEADS, DK)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(batch, KV_HEADS, DV)), jnp.float32)
    return k, v


def _paged_with_identity_table(batch, slots, page_size, extra_pages=2):
    npl = slots // page_size
    num_pages = batch * npl + extra_pages
    cache = kv.init_paged_cache(batch, KV_HEADS, num_pages, npl, page_size,
                                DK, DV, jnp.float32)
    table = np.stack(
        [np.arange(b * npl, (b + 1) * npl) for b in range(batch)]
    ).astype(np.int32)
    return dataclasses.replace(cache, page_table=jnp.asarray(table))


# ---------------------------------------------------------------------------
# Paged vs contiguous: slot-for-slot identity across policies
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    batch=st.integers(min_value=1, max_value=3),
    page_size=st.sampled_from([4, 8]),
    policy=st.sampled_from(["full", "window", "aqua-mem"]),
    steps=st.integers(min_value=1, max_value=40),
)
def test_paged_matches_contiguous(seed, batch, page_size, policy, steps):
    rng = np.random.default_rng(seed)
    slots = 16
    window = 8 if policy == "window" else None
    dk = 4 if policy == "aqua-mem" else DK  # AQUA-Memory static slice
    cont = kv.init_attn_cache(batch, KV_HEADS, slots, dk, DV, jnp.float32)
    paged = _paged_with_identity_table(batch, slots, page_size)
    if dk != DK:
        paged = dataclasses.replace(
            paged, k_pool=paged.k_pool[..., :dk])
    mask_seq = rng.random(steps) < 0.8  # interleave frozen-lane steps
    for t in range(steps):
        k, v = _rand_kv(rng, batch)
        k = k[..., :dk]
        wm = None
        if not mask_seq[t]:
            wm = jnp.asarray(rng.random(batch) < 0.5)
        slot = kv.select_slot(cont, window=window, h2o=False, recent_len=0)
        pslot, evict = kv.paged_select_slot(paged, window=window, h2o=False,
                                            recent_len=0)
        assert evict is None
        np.testing.assert_array_equal(np.asarray(slot), np.asarray(pslot))
        cont = kv.insert(cont, slot, k, v, write_mask=wm)
        paged = kv.paged_insert(paged, pslot, k, v, write_mask=wm)
    view = kv.paged_lane_view(paged)
    np.testing.assert_array_equal(np.asarray(cont.k), np.asarray(view.k))
    np.testing.assert_array_equal(np.asarray(cont.v), np.asarray(view.v))
    np.testing.assert_array_equal(np.asarray(cont.positions),
                                  np.asarray(view.positions))
    np.testing.assert_array_equal(np.asarray(cont.count),
                                  np.asarray(view.count))
    # positions consistent with count: every valid position < count
    pos = np.asarray(view.positions)
    cnt = np.asarray(view.count)
    assert (pos[pos >= 0] < cnt.repeat(pos.shape[1]).reshape(pos.shape)[
        pos >= 0]).all()
    # decode identity: masked softmax attention over both layouts
    q = jnp.asarray(rng.normal(size=(batch, KV_HEADS, 2, dk)), jnp.float32)
    from repro.core.attention import _masked_dense_decode_core
    out_c, _ = _masked_dense_decode_core(
        q, cont.k, cont.v, cont.positions, cont.count,
        head_dim=DK, window=window)
    out_p, _ = _masked_dense_decode_core(
        q, view.k, view.v, view.positions, view.count,
        head_dim=DK, window=window)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_p))


# ---------------------------------------------------------------------------
# Page-granular H2O: device victim choice matches the numpy oracle, and
# freed (evicted) pages really read as empty afterwards
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    page_size=st.sampled_from([4, 8]),
    recent_len=st.integers(min_value=1, max_value=8),
)
def test_paged_h2o_page_eviction(seed, page_size, recent_len):
    rng = np.random.default_rng(seed)
    slots = 16
    paged = _paged_with_identity_table(1, slots, page_size)
    for t in range(3 * slots):
        k, v = _rand_kv(rng, 1)
        slot, evict = kv.paged_select_slot(paged, window=None, h2o=True,
                                           recent_len=recent_len)
        pos_before = np.asarray(kv.gather_positions(paged))[0]
        acc_before = np.asarray(kv.paged_lane_view(paged).acc_score)[0]
        expect = reference_victim_page(
            pos_before, acc_before, int(paged.count[0]),
            page_size=page_size, recent_len=recent_len)
        ev = int(np.asarray(evict)[0])
        assert ev == expect, (t, ev, expect)
        if ev >= 0:
            assert int(slot[0]) == ev * page_size
        paged = kv.paged_insert(paged, slot, k, v, evict_page=evict)
        # fake an H2O accumulation step so scores differentiate pages
        w = jnp.asarray(rng.random((1, KV_HEADS, 2, slots)), jnp.float32)
        w = w * (jnp.asarray(pos_before >= 0) | (jnp.arange(slots)
                                                 == int(slot[0])))[None,
                                                                   None,
                                                                   None]
        paged = kv.paged_accumulate_h2o(paged, w)
        if ev >= 0:
            # the freed page holds exactly one token now (the insert)
            pos = np.asarray(kv.gather_positions(paged))[0]
            page = pos[ev * page_size:(ev + 1) * page_size]
            assert (page[1:] == -1).all()
            assert page[0] == int(paged.count[0]) - 1


# ---------------------------------------------------------------------------
# Allocator invariants under random admit/retire interleavings
# ---------------------------------------------------------------------------


def _check_pool_invariants(pool, lanes):
    mapped = {}
    for lane, pages in lanes.items():
        for p in pages:
            mapped.setdefault(p, []).append(lane)
    for p, owners in mapped.items():
        assert pool.refcount[p] == len(owners), (p, owners)
        assert p not in pool._free, f"free page {p} is referenced"
        if len(owners) > 1:  # shared pages must be prefix-registered
            assert p in pool._page_key, f"page {p} shared but unregistered"
    for p in pool._free:
        assert pool.refcount[p] == 0
    assert len(pool._free) + len(mapped) == pool.num_pages


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    num_pages=st.integers(min_value=4, max_value=16),
    share=st.sampled_from([True, False]),
    ops=st.integers(min_value=5, max_value=60),
)
def test_page_pool_invariants(seed, num_pages, share, ops):
    rng = np.random.default_rng(seed)
    ps = 4
    pool = PagePool(num_pages, ps, prefix_sharing=share)
    lanes = {}
    next_lane = 0
    common = rng.integers(0, 50, size=(2 * ps,), dtype=np.int32)
    for _ in range(ops):
        if lanes and rng.random() < 0.4:  # retire a random lane
            lane = int(rng.choice(list(lanes)))
            pool.release(lane)
            del lanes[lane]
        else:  # admit: half the prompts share a common prefix
            if rng.random() < 0.5:
                tail = rng.integers(0, 50, size=(int(rng.integers(1, 6)),),
                                    dtype=np.int32)
                tokens = np.concatenate([common, tail])
            else:
                tokens = rng.integers(0, 50,
                                      size=(int(rng.integers(1, 12)),),
                                      dtype=np.int32)
            shared = pool.lookup_prefix(tokens)
            shared = shared[:max(0, (len(tokens) - 1) // ps)]
            total = -(-(len(tokens) + 2) // ps)  # + decode reservation
            num_new = total - len(shared)
            pages = pool.reserve(next_lane, shared, num_new) \
                if pool.can_reserve(num_new) else None
            if pages is not None:
                lanes[next_lane] = pages
                pool.register_prefix(tokens, pages, len(tokens))
                next_lane += 1
        _check_pool_invariants(pool, lanes)
    for lane in list(lanes):
        pool.release(lane)
        del lanes[lane]
        _check_pool_invariants(pool, lanes)
    assert pool.pages_in_use == 0


def test_page_pool_copy_on_write():
    """make_private splits a shared page: refcounts rebalance, the copy is
    unindexed, and the donor keeps its page."""
    pool = PagePool(6, 4, prefix_sharing=True)
    toks = np.arange(8, dtype=np.int32)
    a = pool.reserve(0, [], 2)
    pool.register_prefix(toks, a, 8)
    shared = pool.lookup_prefix(toks)
    assert shared == a[:2][: len(shared)] and len(shared) == 2
    b = pool.reserve(1, shared[:1], 1)
    assert pool.refcount[a[0]] == 2
    moved = pool.make_private(1, 0)
    assert moved is not None and moved[0] == a[0]
    assert pool.refcount[a[0]] == 1 and pool.refcount[moved[1]] == 1
    assert pool.make_private(1, 0) is None  # already private
    assert pool.lane_pages(1)[0] == moved[1]
    assert b[0] == a[0]          # reserve really mapped the shared page
    pool.release(0)
    pool.release(1)
    assert pool.pages_in_use == 0
    assert not pool._prefix_index  # freed pages leave the index


def test_paged_reset_lane_clears_only_that_lane():
    rng = np.random.default_rng(0)
    paged = _paged_with_identity_table(2, 8, 4)
    for _ in range(6):
        k, v = _rand_kv(rng, 2)
        slot, _ = kv.paged_select_slot(paged, window=None, h2o=False,
                                       recent_len=0)
        paged = kv.paged_insert(paged, slot, k, v)
    before = np.asarray(kv.gather_positions(paged))
    reset = kv.paged_reset_lane(paged, jnp.int32(0))
    after_pos = np.asarray(kv.gather_positions(reset))
    assert (np.asarray(reset.page_table)[0] == -1).all()
    assert (after_pos[0] == -1).all()
    np.testing.assert_array_equal(after_pos[1], before[1])
    assert int(reset.count[0]) == 0 and int(reset.count[1]) == 6


# ---------------------------------------------------------------------------
# Quantized pools (QuantSpec int8): round-trip error bound, frozen-lane
# write masks, hot/cold precision policy, CoW scale metadata
# ---------------------------------------------------------------------------


def _paged_quant(batch, slots, page_size, gran="page_head", hot_pages=0,
                 extra_pages=2):
    npl = slots // page_size
    num_pages = batch * npl + extra_pages
    cache = kv.init_paged_cache(batch, KV_HEADS, num_pages, npl, page_size,
                                DK, DV, jnp.float32, kv_dtype="int8",
                                scale_granularity=gran, hot_pages=hot_pages)
    table = np.stack(
        [np.arange(b * npl, (b + 1) * npl) for b in range(batch)]
    ).astype(np.int32)
    return dataclasses.replace(cache, page_table=jnp.asarray(table))


def _quant_bound(cache, inserts_per_page):
    """Per-(page, kv-head, slot) round-trip bound: one half-scale rounding
    per insert that could have regrown the page's running scale, plus the
    token's own quantization step."""
    ps = cache.page_size
    s = np.asarray(cache.k_scale, np.float64)            # (P, SH)
    n = inserts_per_page[:, None] + 1.0                  # (P, 1)
    per_page = 0.5 * s * n + 1e-6                        # (P, SH)
    return np.broadcast_to(per_page[:, :, None],
                           (s.shape[0], KV_HEADS, ps))


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    page_size=st.sampled_from([4, 8]),
    gran=st.sampled_from(["page_head", "page"]),
    kv_dtype=st.sampled_from(["int8", "bf16"]),
)
def test_quant_roundtrip_error_bound(seed, page_size, gran, kv_dtype):
    """dequant(quant(page)) error stays within the per-dtype bound: int8
    pays at most half a (running) scale per insert that touched the page;
    bf16 pools pay one bf16 rounding (2^-8 relative)."""
    rng = np.random.default_rng(seed)
    batch, slots = 2, 16
    if kv_dtype == "int8":
        paged = _paged_quant(batch, slots, page_size, gran=gran)
    else:
        npl = slots // page_size
        paged = _paged_with_identity_table(batch, slots, page_size)
        paged = dataclasses.replace(
            paged, k_pool=paged.k_pool.astype(jnp.bfloat16),
            v_pool=paged.v_pool.astype(jnp.bfloat16))
    cont = kv.init_attn_cache(batch, KV_HEADS, slots, DK, DV, jnp.float32)
    inserts = np.zeros(paged.num_pages)
    for _ in range(slots):
        k, v = _rand_kv(rng, batch)
        slot = kv.select_slot(cont, window=None, h2o=False, recent_len=0)
        pslot, _ = kv.paged_select_slot(paged, window=None, h2o=False,
                                        recent_len=0)
        phys = np.asarray(paged.page_table)[np.arange(batch),
                                            np.asarray(pslot) // page_size]
        inserts[phys[phys >= 0]] += 1
        cont = kv.insert(cont, slot, k, v)
        paged = kv.paged_insert(paged, pslot, k, v)
    view = kv.paged_lane_view(paged)
    err = np.abs(np.asarray(cont.k, np.float64)
                 - np.asarray(view.k, np.float64))       # (B, KV, S, DK)
    if kv_dtype == "int8":
        bound = _quant_bound(paged, inserts)             # (P, KV, ps)
        tbl = np.asarray(paged.page_table)               # (B, NP)
        per_slot = bound[tbl].transpose(0, 2, 1, 3)      # (B, KV, NP, ps)
        per_slot = per_slot.reshape(batch, KV_HEADS, slots)
        assert (err <= per_slot[..., None]).all(), err.max()
        # the v pool obeys its own scales
        err_v = np.abs(np.asarray(cont.v, np.float64)
                       - np.asarray(view.v, np.float64))
        sv = np.asarray(paged.v_scale, np.float64)
        bv = (0.5 * sv * (inserts[:, None] + 1) + 1e-6)[tbl]
        bv = np.repeat(bv.transpose(0, 2, 1), page_size, axis=2) \
            .reshape(batch, KV_HEADS, slots) if sv.shape[1] > 1 else None
        if bv is not None:
            assert (err_v <= bv[..., None]).all(), err_v.max()
    else:
        amax = np.abs(np.asarray(cont.k, np.float64))
        assert (err <= amax * 2.0**-8 + 1e-6).all(), err.max()


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    page_size=st.sampled_from([4, 8]),
    gran=st.sampled_from(["page_head", "page"]),
    steps=st.integers(min_value=2, max_value=24),
)
def test_write_mask_never_touches_quantized_pages(seed, page_size, gran,
                                                  steps):
    """A frozen lane's int8 pages AND their scale metadata must be
    bit-identical across a masked insert — requant-on-growth must not
    leak into suppressed rows."""
    rng = np.random.default_rng(seed)
    batch, slots = 3, 16
    paged = _paged_quant(batch, slots, page_size, gran=gran)
    for _ in range(steps):
        k, v = _rand_kv(rng, batch)
        wm = rng.random(batch) < 0.5
        pslot, _ = kv.paged_select_slot(paged, window=None, h2o=False,
                                        recent_len=0)
        before_k = np.asarray(paged.k_pool).copy()
        before_s = np.asarray(paged.k_scale).copy()
        before_sv = np.asarray(paged.v_scale).copy()
        after = kv.paged_insert(paged, pslot, k, v,
                                write_mask=jnp.asarray(wm))
        tbl = np.asarray(paged.page_table)
        frozen_pages = set()
        for lane in range(batch):
            if not wm[lane]:
                frozen_pages.update(int(p) for p in tbl[lane] if p >= 0)
        for p in frozen_pages:
            np.testing.assert_array_equal(np.asarray(after.k_pool)[p],
                                          before_k[p])
            np.testing.assert_array_equal(np.asarray(after.k_scale)[p],
                                          before_s[p])
            np.testing.assert_array_equal(np.asarray(after.v_scale)[p],
                                          before_sv[p])
        paged = after
    # at least the masked lanes' counts froze too
    assert int(paged.count.max()) <= steps


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    page_size=st.sampled_from([4]),
    steps=st.integers(min_value=8, max_value=40),
)
def test_hot_cold_precision_policy_invariant(seed, page_size, steps):
    """Mixed precision under random insert/evict interleavings: the int8
    pool stays authoritative (cold pages within the quant bound of the
    exact oracle), hot residents read exactly (write-through), and a
    freed page is never served by a stale overlay."""
    rng = np.random.default_rng(seed)
    batch, slots = 1, 16
    paged = _paged_quant(batch, slots, page_size, hot_pages=2)
    # promote lane 0's first two (still-empty) pages as hot residents
    paged = dataclasses.replace(paged,
                                hot_ids=jnp.asarray([0, 1], jnp.int32))
    npg = paged.num_pages
    ek = np.zeros((npg, KV_HEADS, page_size, DK))        # exact fp oracle
    ev_ = np.zeros((npg, KV_HEADS, page_size, DV))
    inserts = np.zeros(npg)
    for t in range(steps):
        k, v = _rand_kv(rng, batch)
        pslot, evict = kv.paged_select_slot(paged, window=None, h2o=True,
                                            recent_len=2)
        tbl = np.asarray(paged.page_table)
        ev_np = np.asarray(evict)
        freed = [int(tbl[b_, e]) for b_, e in enumerate(ev_np)
                 if e >= 0 and tbl[b_, e] >= 0]
        for p in freed:
            ek[p] = 0.0
            ev_[p] = 0.0
            inserts[p] = 0
        paged = kv.paged_insert(paged, pslot, k, v, evict_page=evict)
        phys = tbl[np.arange(batch), np.asarray(pslot) // page_size]
        off = np.asarray(pslot) % page_size
        for b_ in range(batch):
            if phys[b_] >= 0:
                ek[phys[b_], :, off[b_]] = np.asarray(k)[b_]
                ev_[phys[b_], :, off[b_]] = np.asarray(v)[b_]
                inserts[phys[b_]] += 1
        w = jnp.asarray(rng.random((batch, KV_HEADS, 2, slots)), jnp.float32)
        paged = kv.paged_accumulate_h2o(paged, w)
        hot = np.asarray(paged.hot_ids)
        # freed pages must have been demoted this very step
        assert not (set(hot[hot >= 0]) & set(freed)), (hot, freed)
        # residents only ever reference currently-mapped pages
        mapped = set(int(p) for p in np.asarray(paged.page_table).ravel()
                     if p >= 0)
        assert set(int(h) for h in hot if h >= 0) <= mapped
        # hot overlay is exact; cold pages obey the int8 bound
        valid = np.asarray(paged.pos_pool) >= 0          # (P, ps)
        deq = np.asarray(kv.dequant_pages(paged.k_pool, paged.k_scale),
                         np.float64)
        bound = _quant_bound(paged, inserts)             # (P, KV, ps)
        kh = np.asarray(paged.k_hot, np.float64)
        for p in range(npg):
            if not valid[p].any():
                continue
            m = valid[p]
            hs = np.where(hot == p)[0]
            if hs.size:
                np.testing.assert_array_equal(kh[hs[0]][:, m], ek[p][:, m])
            err = np.abs(deq[p] - ek[p])[:, m]
            assert (err <= bound[p][:, m][..., None]).all(), (p, err.max())


def test_copy_on_write_preserves_scale_metadata():
    """paged_copy_page (the device half of PagePool.make_private) must
    move the int8 ints AND the per-page scales together — a CoW split
    that dropped the scales would dequantize the copy to garbage."""
    rng = np.random.default_rng(0)
    paged = _paged_quant(1, 16, 4)
    for _ in range(9):
        k, v = _rand_kv(rng, 1)
        pslot, _ = kv.paged_select_slot(paged, window=None, h2o=False,
                                        recent_len=0)
        paged = kv.paged_insert(paged, pslot, k, v)
    src, dst = 1, paged.num_pages - 1                     # dst is a free page
    view_before = np.asarray(kv.paged_lane_view(paged).k)
    copied = kv.paged_copy_page(paged, jnp.int32(src), jnp.int32(dst))
    np.testing.assert_array_equal(np.asarray(copied.k_pool)[dst],
                                  np.asarray(copied.k_pool)[src])
    np.testing.assert_array_equal(np.asarray(copied.k_scale)[dst],
                                  np.asarray(copied.k_scale)[src])
    np.testing.assert_array_equal(np.asarray(copied.v_scale)[dst],
                                  np.asarray(copied.v_scale)[src])
    np.testing.assert_array_equal(np.asarray(copied.pos_pool)[dst],
                                  np.asarray(copied.pos_pool)[src])
    # remap the lane's page 1 to the copy: the dequantized view must be
    # bit-identical (same ints × same scale)
    tbl = np.asarray(copied.page_table).copy()
    tbl[0, src] = dst
    remapped = dataclasses.replace(copied, page_table=jnp.asarray(tbl))
    np.testing.assert_array_equal(np.asarray(kv.paged_lane_view(remapped).k),
                                  view_before)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])


# ---------------------------------------------------------------------------
# Hierarchical stage-1 selection (SparsitySpec): the jit ranking equals
# the numpy --verify oracle, the recency pin is an invariant, and shared
# (CoW / prefix) physical pages are ranked per-lane
# ---------------------------------------------------------------------------


def _int_acc_pool(rng, num_pages, page_size):
    """Integer-valued float32 mass: the jit path and the numpy oracle sum
    in different orders, so exactness (not tolerance) requires sums that
    float32 represents exactly."""
    return jnp.asarray(
        rng.integers(0, 8, size=(num_pages, KV_HEADS, page_size)),
        jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    batch=st.integers(min_value=1, max_value=3),
    page_size=st.sampled_from([4, 8]),
    npl=st.sampled_from([4, 8]),
    pin=st.integers(min_value=1, max_value=3),
)
def test_participating_pages_matches_numpy_oracle(seed, batch, page_size,
                                                  npl, pin):
    from repro.core import selection
    rng = np.random.default_rng(seed)
    num_pages = batch * npl + 2
    acc = _int_acc_pool(rng, num_pages, page_size)
    # per-lane tables: a random physical permutation with a random mapped
    # prefix (the unmapped tail is -1, exactly like a growing lane)
    table = np.full((batch, npl), -1, np.int32)
    count = np.zeros((batch,), np.int32)
    perm = rng.permutation(num_pages)
    nxt = 0
    for i in range(batch):
        mapped = int(rng.integers(1, npl + 1))
        table[i, :mapped] = perm[nxt:nxt + mapped]
        nxt += mapped
        count[i] = int(rng.integers(1, mapped * page_size + 1))
    kept = int(rng.integers(pin, npl + 1))
    got = selection.participating_pages(
        acc, jnp.asarray(table), jnp.asarray(count), page_size=page_size,
        kept_pages=kept, pin_recent_pages=pin)
    ref = selection.reference_participating_pages(
        acc, table, count, page_size=page_size, kept_pages=kept,
        pin_recent_pages=pin)
    np.testing.assert_array_equal(np.asarray(got), ref)
    # output is always sorted ascending with in-range logical indices
    g = np.asarray(got)
    assert (np.sort(g, axis=1) == g).all()
    assert (g >= 0).all() and (g < npl).all()


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    page_size=st.sampled_from([4, 8]),
    pin=st.integers(min_value=1, max_value=3),
)
def test_recency_pin_is_invariant(seed, page_size, pin):
    """However adversarial the mass distribution, the pages holding the
    most recent tokens are always in the participating set."""
    from repro.core import selection
    rng = np.random.default_rng(seed)
    npl = 8
    acc = _int_acc_pool(rng, npl + 2, page_size) * 1000.0  # huge elsewhere
    table = jnp.arange(npl, dtype=jnp.int32)[None]
    cnt = int(rng.integers(1, npl * page_size + 1))
    kept = int(rng.integers(pin, npl + 1))
    part = np.asarray(selection.participating_pages(
        acc, table, jnp.asarray([cnt], jnp.int32), page_size=page_size,
        kept_pages=kept, pin_recent_pages=pin))[0]
    tail = max((cnt - 1) // page_size, 0)
    pinned = set(range(max(tail - pin + 1, 0), tail + 1))
    missing = pinned - set(part.tolist())
    assert len(pinned) <= kept and not missing, (cnt, part, pinned)


def test_shared_pages_rank_per_lane():
    """A CoW/prefix-shared physical page contributes its mass to every
    lane that maps it, at each lane's own logical position — ranking
    gathers through the table, never through pool order."""
    from repro.core import selection
    ps, npl = 4, 4
    acc = jnp.zeros((6, KV_HEADS, ps), jnp.float32).at[5].set(9.0)
    # both lanes map hot physical page 5, at logical 0 vs logical 2
    table = jnp.asarray([[5, 0, 1, 2],
                         [3, 4, 5, 2]], jnp.int32)
    count = jnp.full((2,), npl * ps, jnp.int32)
    part = np.asarray(selection.participating_pages(
        acc, table, count, page_size=ps, kept_pages=2,
        pin_recent_pages=1))
    np.testing.assert_array_equal(part[0], [0, 3])   # hot page + pin
    np.testing.assert_array_equal(part[1], [2, 3])   # same page, lane 1


def test_full_keep_is_identity_regardless_of_mass():
    from repro.core import selection
    rng = np.random.default_rng(0)
    ps, npl = 4, 8
    acc = _int_acc_pool(rng, npl, ps)
    table = jnp.arange(npl, dtype=jnp.int32)[None]
    part = np.asarray(selection.participating_pages(
        acc, table, jnp.asarray([npl * ps], jnp.int32), page_size=ps,
        kept_pages=npl, pin_recent_pages=2))
    np.testing.assert_array_equal(part[0], np.arange(npl))
