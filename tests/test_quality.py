"""Oracle tests for the quality bench (benchmarks/quality.py): the
teacher-forced perplexity helper pinned against a hand-rolled numpy CE,
ppl monotone (nondecreasing) as k_ratio shrinks on a trained model, and
golden-shape/finiteness checks for the HF-ingestion quality rows."""

import dataclasses
import math

import jax
import numpy as np
import pytest

from benchmarks.compare import _metrics
from benchmarks.quality import (hf_ingest_quality, match_fraction,
                                ppl_and_accuracy, teacher_forced_ppl)
from hf_fixtures import make_fixture
from repro.checkpoint.hf import load_hf_checkpoint
from repro.configs import reduced
from repro.configs.base import AquaConfig, TrainConfig
from repro.core.calibration import calibrate
from repro.data.pipeline import DataConfig, calibration_batches, make_batch
from repro.launch.train import Trainer
from repro.models import build_model


def test_ppl_matches_numpy_ce_oracle(tmp_path):
    """teacher_forced_ppl == exp(mean -log softmax[label]), hand-rolled
    token by token from the model's own logits, to 1e-5 relative."""
    outdir, cfg, _ = make_fixture(tmp_path)
    params = load_hf_checkpoint(outdir, cfg)
    model = build_model(cfg)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=12, global_batch=2,
                      seed=3, kind="uniform")
    batches = [make_batch(dcfg, i) for i in range(2)]
    got = teacher_forced_ppl(cfg, params, None, batches)

    nlls = []
    for b in batches:
        logits = np.asarray(
            model.forward(params, {"tokens": b["tokens"]}), np.float64)
        labels = np.asarray(b["labels"])
        for bi in range(labels.shape[0]):
            for t in range(labels.shape[1]):
                row = logits[bi, t]
                prob = np.exp(row - row.max())
                prob /= prob.sum()
                nlls.append(-math.log(prob[labels[bi, t]]))
    want = math.exp(float(np.mean(nlls)))
    assert got == pytest.approx(want, rel=1e-5)
    assert math.isfinite(got) and got > 0


def test_ppl_respects_loss_mask(tmp_path):
    outdir, cfg, _ = make_fixture(tmp_path)
    params = load_hf_checkpoint(outdir, cfg)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2,
                      seed=1, kind="copy")
    b = make_batch(dcfg, 0)                # carries a loss_mask
    masked, _ = ppl_and_accuracy(cfg, params, None, [b])
    unmasked, _ = ppl_and_accuracy(
        cfg, params, None, [{"tokens": b["tokens"], "labels": b["labels"]}])
    assert masked != pytest.approx(unmasked, rel=1e-9)


@pytest.fixture(scope="module")
def trained_lcg():
    """Small qwen3-family model trained on the learnable LCG language —
    partially converged (60 steps), so the AQUA approximation level is
    visible in the teacher-forced ppl."""
    cfg = dataclasses.replace(reduced("qwen3-0.6b", vocab=64), remat=False)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=16)
    trainer = Trainer(cfg, tcfg, dcfg, donate=False)
    state, _ = trainer.run(60, log_every=1000)
    model = build_model(cfg)

    def fwd_cap(p, batch):
        _, aux = model.forward(p, batch, capture=True)
        return aux

    proj = calibrate(fwd_cap, state.params,
                     calibration_batches(cfg, num_batches=2, batch=2,
                                         seq=32), cfg)
    return cfg, state.params, proj, dcfg


def test_ppl_monotone_nondecreasing_in_k_ratio(trained_lcg):
    cfg, params, proj, dcfg = trained_lcg
    held = [make_batch(dcfg, 40_000 + i) for i in range(3)]
    ppls = []
    for k in (1.0, 0.75, 0.5, 0.25):
        ck = dataclasses.replace(cfg, aqua=AquaConfig(k_ratio=k))
        ppl, acc = ppl_and_accuracy(ck, params, proj, held)
        assert math.isfinite(ppl) and 0.0 <= acc <= 1.0
        ppls.append(ppl)
    exact, _ = ppl_and_accuracy(
        dataclasses.replace(cfg, aqua=None), params, None, held)
    # k=1.0 is a pure rotation: ppl identical to exact up to float ulps
    assert ppls[0] == pytest.approx(exact, rel=1e-4)
    # dropping more dims can only lose information (tiny slack for the
    # float reduction-order noise between adjacent operating points)
    for hi, lo in zip(ppls, ppls[1:]):
        assert lo >= hi * (1 - 1e-4), ppls


def test_match_fraction_counts_positions():
    class Out:
        def __init__(self, toks):
            self.tokens = toks

    ref = {0: Out([1, 2, 3, 4]), 1: Out([5, 6])}
    same = {0: Out([1, 2, 3, 4]), 1: Out([5, 6])}
    assert match_fraction(same, ref) == 1.0
    half = {0: Out([1, 2, 9, 9]), 1: Out([5, 6])}
    assert match_fraction(half, ref) == pytest.approx(4 / 6)
    short = {0: Out([1, 2]), 1: Out([5, 6])}   # missing tail = mismatch
    assert match_fraction(short, ref) == pytest.approx(4 / 6)


def test_hf_ingest_quality_rows_golden():
    rows = hf_ingest_quality()
    names = [r[0] for r in rows]
    for k in ("1", "0.75", "0.5"):
        assert f"quality/hf_ppl_k{k}" in names
        assert f"quality/hf_match_k{k}@mesh2x2" in names
    metrics = {}
    for name, us, derived in rows:
        assert us == 0.0
        for m, v in _metrics(derived).items():
            assert math.isfinite(v), (name, m)
        metrics[name] = _metrics(derived)
    for k in ("1", "0.75", "0.5"):
        assert metrics[f"quality/hf_ppl_k{k}"]["ppl"] > 0
    # nondecreasing ppl across the sweep (same held-out windows)
    assert metrics["quality/hf_ppl_k0.5"]["ppl"] >= \
        metrics["quality/hf_ppl_k1"]["ppl"] * (1 - 1e-4)
    if jax.device_count() >= 4:
        # full-kept rotation on the mesh kernel path: token-identical
        assert metrics["quality/hf_match_k1@mesh2x2"]["token_match"] == 1.0
