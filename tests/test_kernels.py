"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs the
pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aqua import chunk_topk_block_indices, topk_block_indices
from repro.kernels.ops import (aqua_decode, aqua_prefill, flash_attention,
                               round_k_dims, to_dim_major_blocks)
from repro.kernels.ref import (aqua_decode_ref, aqua_prefill_ref,
                               flash_attention_ref)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,d", [
    (1, 2, 2, 128, 32),
    (2, 4, 2, 256, 64),
    (2, 8, 2, 384, 64),   # GQA group 4, padded seq blocks
    (1, 4, 4, 256, 128),  # MHA
])
@pytest.mark.parametrize("k_ratio", [0.5, 0.75, 1.0])
def test_aqua_decode_matches_oracle(b, h, kv, s, d, dtype, k_ratio):
    ks = jax.random.split(jax.random.PRNGKey(42), 4)
    q = _rand(ks[0], (b, h, d), dtype)
    khat = _rand(ks[1], (b, kv, s, d), dtype)
    v = _rand(ks[2], (b, kv, s, d), dtype)
    lengths = jnp.full((b,), s, jnp.int32).at[0].set(max(1, s - 37))
    out = aqua_decode(q, khat, v, lengths, k_ratio=k_ratio, block_dims=8,
                      seq_blk=128)
    k_dims = min(d, max(8, int(round(k_ratio * d)) // 8 * 8))
    bi = topk_block_indices(q, k_dims, 8)
    ref = aqua_decode_ref(q, khat, v, bi, lengths, 8)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_aqua_decode_full_ratio_equals_exact_attention():
    """k_ratio=1.0 must reproduce exact softmax attention."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    b, h, kv, s, d = 1, 2, 1, 128, 32
    q = _rand(ks[0], (b, h, d), jnp.float32)
    khat = _rand(ks[1], (b, kv, s, d), jnp.float32)
    v = _rand(ks[2], (b, kv, s, d), jnp.float32)
    lengths = jnp.full((b,), s, jnp.int32)
    out = aqua_decode(q, khat, v, lengths, k_ratio=1.0, block_dims=8)
    qr = q.reshape(b, kv, h // kv, d)
    sc = jnp.einsum("bkgd,bksd->bkgs", qr, khat) / np.sqrt(d)
    w = jax.nn.softmax(sc, -1)
    ref = jnp.einsum("bkgs,bksd->bkgd", w, v).reshape(b, h, d)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_dim_major_blocks_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 64, 32))
    blk = to_dim_major_blocks(x, 8)
    assert blk.shape == (2, 3, 4, 8, 64)
    back = blk.reshape(2, 3, 32, 64).transpose(0, 1, 3, 2)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,d,window", [
    (1, 2, 2, 256, 32, None),
    (2, 4, 2, 256, 64, None),
    (1, 4, 1, 384, 64, 100),   # MQA + sliding window
    (1, 2, 2, 512, 128, 256),
])
def test_flash_attention_matches_oracle(b, h, kv, s, d, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (b, h, s, d), dtype)
    k = _rand(ks[1], (b, kv, s, d), dtype)
    v = _rand(ks[2], (b, kv, s, d), dtype)
    out = flash_attention(q, k, v, causal=True, window=window)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = _rand(ks[0], (1, 2, 128, 32), jnp.float32)
    k = _rand(ks[1], (1, 2, 128, 32), jnp.float32)
    v = _rand(ks[2], (1, 2, 128, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=False)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# AQUA block-sparse chunked prefill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,d,q_blk,k_blk,window", [
    (1, 2, 2, 64, 32, 16, 16, None),
    (2, 4, 2, 96, 32, 16, 32, None),    # GQA 2, ragged pad to chunk lcm
    (2, 8, 2, 128, 64, 32, 32, 24),     # GQA 4 + sliding window
    (1, 4, 4, 64, 64, 8, 16, None),     # MHA, small chunks
])
@pytest.mark.parametrize("k_ratio", [0.5, 0.75, 1.0])
def test_aqua_prefill_matches_oracle(b, h, kv, s, d, q_blk, k_blk, window,
                                     k_ratio, dtype):
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = _rand(ks[0], (b, h, s, d), dtype)
    khat = _rand(ks[1], (b, kv, s, d), dtype)
    v = _rand(ks[2], (b, kv, s, d), dtype)
    lengths = jnp.full((b,), s, jnp.int32).at[0].set(max(1, s - 13))
    out = aqua_prefill(q, khat, v, lengths, k_ratio=k_ratio, block_dims=8,
                       q_blk=q_blk, k_blk=k_blk, window=window)
    k_dims = round_k_dims(d, k_ratio, 8)
    bi = chunk_topk_block_indices(q, k_dims, 8, q_blk, lengths)
    ref = aqua_prefill_ref(q, khat, v, bi, lengths, 8, q_blk, window=window)
    sq = jnp.arange(s) < lengths[:, None]       # compare valid rows only
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(
        np.asarray(jnp.where(sq[:, None, :, None], out, 0), np.float32),
        np.asarray(jnp.where(sq[:, None, :, None], ref, 0), np.float32),
        rtol=tol, atol=tol)


def test_aqua_prefill_full_ratio_equals_flash():
    """k_ratio=1.0 streams every dim-block -> exact causal attention."""
    ks = jax.random.split(jax.random.PRNGKey(12), 3)
    b, h, kv, s, d = 1, 4, 2, 128, 32
    q = _rand(ks[0], (b, h, s, d), jnp.float32)
    k = _rand(ks[1], (b, kv, s, d), jnp.float32)
    v = _rand(ks[2], (b, kv, s, d), jnp.float32)
    out = aqua_prefill(q, k, v, None, k_ratio=1.0, block_dims=8,
                       q_blk=32, k_blk=32)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_aqua_prefill_chunk1_equals_per_query_selection():
    """q_blk=1 chunk selection must reduce to the paper's per-query top-k."""
    ks = jax.random.split(jax.random.PRNGKey(13), 1)[0]
    q = _rand(ks, (1, 2, 16, 32), jnp.float32)
    per_chunk = chunk_topk_block_indices(q, 16, 8, 1)
    per_query = topk_block_indices(q, 16, 8)
    np.testing.assert_array_equal(np.asarray(per_chunk),
                                  np.asarray(per_query))
