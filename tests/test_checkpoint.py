"""Checkpoint manager: atomicity, keep-N, roundtrip, async, elastic."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                   "c": [jnp.ones((2,)), jnp.zeros((3, 3), jnp.bfloat16)]},
        "scalar": jnp.float32(3.5),
    }


def test_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    m.save(10, t)
    restored, step = m.restore(None, jax.eval_shape(lambda: t))
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_n(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, _tree())
    assert m.all_steps() == [3, 4]


def test_async_save(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(5, _tree(), blocking=False)
    m.wait()
    assert m.latest_step() == 5


def test_atomic_no_tmp_left(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(7, _tree())
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_restore_shape_mismatch_raises(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(1, {"x": jnp.ones((4,))})
    with pytest.raises(ValueError):
        m.restore(1, {"x": jnp.ones((5,))})


def test_restore_missing_raises(tmp_path):
    m = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        m.restore(None, {})


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_roundtrip_property(seed, tmp_path_factory):
    tmp = tmp_path_factory.mktemp(f"ck{seed}")
    m = CheckpointManager(str(tmp))
    t = _tree(seed)
    m.save(seed, t)
    r, _ = m.restore(seed, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_elastic_restore_onto_different_mesh(tmp_path):
    """Checkpoints are logically unsharded: save on a 1-device layout,
    restore with explicit (1,1) mesh shardings — the reshard-on-load path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    m = CheckpointManager(str(tmp_path))
    t = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 16))}
    m.save(3, t)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P(None, "model"))}
    restored, _ = m.restore(3, t, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(t["w"]))


def test_preemption_resume_exact(tmp_path):
    """Kill-and-resume must reproduce the uninterrupted run exactly."""
    from repro.configs import reduced
    from repro.configs.base import TrainConfig
    from repro.data.pipeline import DataConfig
    from repro.launch.train import Trainer
    import dataclasses
    cfg = dataclasses.replace(reduced("qwen3-0.6b"), remat=False,
                              num_layers=2)
    tcfg = TrainConfig(total_steps=8, warmup_steps=2, checkpoint_every=4,
                       learning_rate=1e-3)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)

    # uninterrupted 8 steps
    t1 = Trainer(cfg, tcfg, dcfg, ckpt_dir=None, donate=False)
    s1, _ = t1.run(8, log_every=100)

    # 4 steps, "preemption", resume 4 more
    ck = str(tmp_path / "ck")
    t2 = Trainer(cfg, tcfg, dcfg, ckpt_dir=ck, donate=False)
    t2.run(4, log_every=100)
    t3 = Trainer(cfg, tcfg, dcfg, ckpt_dir=ck, donate=False)  # fresh process
    s3, _ = t3.run(4, log_every=100)

    assert int(s1.step) == int(s3.step) == 8
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s3.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)
