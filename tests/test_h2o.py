"""H2O eviction-policy tests (paper §8.3 coupling)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AquaConfig, AttentionConfig
from repro.core import attention as attn
from repro.core import kvcache as kv
from repro.core.h2o import h2o_budget, reference_keep_set


def _cache(b=1, kvh=1, slots=8, d=4):
    return kv.init_attn_cache(b, kvh, slots, d, d, jnp.float32)


def test_h2o_budget():
    assert h2o_budget(None, 1000) is None
    assert h2o_budget(AquaConfig(h2o_ratio=1.0), 1000) is None
    assert h2o_budget(AquaConfig(h2o_ratio=0.25), 1000) == 250


def test_select_slot_fills_before_evicting():
    c = _cache(slots=4)
    for i in range(4):
        slot = kv.select_slot(c, window=None, h2o=True, recent_len=2)
        assert int(slot[0]) == i
        c = kv.insert(c, slot, jnp.ones((1, 1, 4)), jnp.ones((1, 1, 4)))
    assert int(c.count[0]) == 4


def test_h2o_evicts_lowest_score_nonrecent():
    c = _cache(slots=4)
    for i in range(4):
        slot = kv.select_slot(c, window=None, h2o=True, recent_len=2)
        c = kv.insert(c, slot, jnp.ones((1, 1, 4)), jnp.ones((1, 1, 4)))
    # incoming pos=4, recent_len=2 protects positions > 2 (slot 3);
    # evictable slots 0,1,2 -> argmin acc = slot 2 (0.1)
    c = dataclasses.replace(
        c, acc_score=jnp.array([[[5.0, 1.0, 0.1, 0.2]]]))
    slot = kv.select_slot(c, window=None, h2o=True, recent_len=2)
    assert int(slot[0]) == 2


def test_h2o_never_evicts_recent():
    c = _cache(slots=4)
    for i in range(4):
        slot = kv.select_slot(c, window=None, h2o=True, recent_len=2)
        c = kv.insert(c, slot, jnp.ones((1, 1, 4)), jnp.ones((1, 1, 4)))
    # global argmin is slot 3 (score 0) but position 3 is protected;
    # the victim must come from the evictable set instead.
    c = dataclasses.replace(
        c, acc_score=jnp.array([[[5.0, 4.0, 3.0, 0.0]]]))
    slot = kv.select_slot(c, window=None, h2o=True, recent_len=2)
    assert int(slot[0]) == 2  # lowest among unprotected slots 0,1,2


def test_ring_window_slot():
    c = _cache(slots=4)
    for i in range(10):
        slot = kv.select_slot(c, window=4, h2o=False, recent_len=0)
        assert int(slot[0]) == i % 4
        c = kv.insert(c, slot, jnp.zeros((1, 1, 4)), jnp.zeros((1, 1, 4)))


def test_valid_mask_window():
    c = _cache(slots=4)
    for i in range(6):
        slot = kv.select_slot(c, window=4, h2o=False, recent_len=0)
        c = kv.insert(c, slot, jnp.zeros((1, 1, 4)), jnp.zeros((1, 1, 4)))
    m = kv.valid_mask(c, window=4)
    # positions held: 4,5,2,3 (ring); current pos=5, window 4 -> valid: 2..5
    np.testing.assert_array_equal(np.asarray(m[0]), [True] * 4)
    m3 = kv.valid_mask(c, window=3)
    pos = np.asarray(c.positions[0])
    np.testing.assert_array_equal(np.asarray(m3[0]), pos > 5 - 3)


def test_reference_keep_set_keeps_recents_and_heavy():
    w = jnp.zeros((8, 8)).at[:, 2].set(1.0)  # token 2 is the heavy hitter
    kept = np.asarray(reference_keep_set(w, budget=3, recent_frac=0.5))
    assert 2 in kept          # heavy hitter
    assert 7 in kept          # most recent


def test_combined_window_h2o_evicts_stale_first():
    """Combined policy: a slot whose position slid out of the window is
    dead (the valid mask never readmits it) — it must be evicted before
    any scored in-window victim, regardless of accumulated mass."""
    c = _cache(slots=4)
    for i in range(4):
        slot = kv.select_slot(c, window=3, h2o=True, recent_len=2)
        c = kv.insert(c, slot, jnp.ones((1, 1, 4)), jnp.ones((1, 1, 4)))
    # incoming pos=4, window=3: position 0 (slot 0) is out-of-window and
    # position 1 (slot 1) is the in-window argmin — stale slot 0 must win
    # even though its accumulated score is the global maximum.
    c = dataclasses.replace(
        c, acc_score=jnp.array([[[9.0, 0.1, 5.0, 5.0]]]))
    slot = kv.select_slot(c, window=3, h2o=True, recent_len=2)
    assert int(slot[0]) == 0


def test_combined_window_h2o_scores_when_no_stale():
    """With every held position in-window, the combined policy reduces to
    scored H2O eviction (recent still protected)."""
    c = _cache(slots=4)
    for i in range(4):
        slot = kv.select_slot(c, window=16, h2o=True, recent_len=2)
        c = kv.insert(c, slot, jnp.ones((1, 1, 4)), jnp.ones((1, 1, 4)))
    c = dataclasses.replace(
        c, acc_score=jnp.array([[[5.0, 1.0, 0.1, 0.2]]]))
    slot = kv.select_slot(c, window=16, h2o=True, recent_len=2)
    assert int(slot[0]) == 2


def test_decode_combined_window_h2o_end_to_end():
    """SWA + H2O decode: cache bounded by min(window, budget), out-of-
    window keys masked, decoding stays finite and positions coherent."""
    acfg = AttentionConfig(num_heads=2, num_kv_heads=1, head_dim=8, window=6)
    aqua = AquaConfig(k_ratio=1.0, h2o_ratio=0.5, block_dims=1)
    d_model = 16
    params = attn.init_attention_params(jax.random.PRNGKey(0), d_model, acfg)
    from repro.core.calibration import identity_projections
    from repro.core.kvcache import cache_slots
    proj = identity_projections(1, 1, 8).p[0]
    max_seq = 16
    slots = cache_slots(max_seq, acfg.window, h2o_budget(aqua, max_seq))
    assert slots == 6            # min(window=6, budget=8)
    cache = kv.init_attn_cache(1, 1, slots, 8, 8, jnp.float32)
    for i in range(14):
        x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(2), i),
                              (1, d_model))
        out, cache = attn.decode_attention(params, x, cache, acfg, aqua, proj)
        assert np.isfinite(np.asarray(out)).all()
    pos = np.asarray(cache.positions[0])
    assert int(cache.count[0]) == 14
    assert len(set(pos.tolist())) == slots           # all slots distinct
    # stale-first eviction keeps the live window resident: every position
    # still attendable (> 14-1-window) is in cache
    m = np.asarray(kv.valid_mask(cache, window=acfg.window)[0])
    assert m.sum() > 0
    assert pos.max() == 13


def test_decode_h2o_cache_stays_within_budget():
    acfg = AttentionConfig(num_heads=2, num_kv_heads=1, head_dim=8)
    aqua = AquaConfig(k_ratio=1.0, h2o_ratio=0.5, block_dims=1)
    d_model = 16
    params = attn.init_attention_params(jax.random.PRNGKey(0), d_model, acfg)
    from repro.core.calibration import identity_projections
    proj = identity_projections(1, 1, 8).p[0]
    max_seq = 16
    budget = h2o_budget(aqua, max_seq)
    cache = kv.init_attn_cache(1, 1, budget, 8, 8, jnp.float32)
    for i in range(12):
        x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(1), i),
                              (1, d_model))
        out, cache = attn.decode_attention(params, x, cache, acfg, aqua, proj)
        assert np.isfinite(np.asarray(out)).all()
    assert cache.num_slots == budget
    assert int(cache.count[0]) == 12
    pos = np.asarray(cache.positions[0])
    assert (pos >= 0).all() and len(set(pos.tolist())) == budget
    # recent tokens always present
    recent = max(1, int(aqua.h2o_recent_frac * budget))
    for p in range(12 - recent, 12):
        assert p in pos
