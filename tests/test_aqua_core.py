"""Unit + property tests for the AQUA core (paper §4, §6, §7)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import aqua

jax.config.update("jax_enable_x64", False)


def random_orthogonal(key, d):
    m = jax.random.normal(key, (d, d))
    q, _ = jnp.linalg.qr(m)
    return q


# ---------------------------------------------------------------------------
# projection computation
# ---------------------------------------------------------------------------


def test_projection_is_orthogonal():
    key = jax.random.PRNGKey(0)
    d_calib = jax.random.normal(key, (512, 32))
    p = aqua.compute_projection(d_calib)
    assert bool(aqua.check_orthogonal(p))


def test_projection_orders_variance_descending():
    key = jax.random.PRNGKey(1)
    # anisotropic data: variance concentrated in a known direction
    base = jax.random.normal(key, (2048, 16))
    scales = jnp.array([10.0 ** (-i / 4) for i in range(16)])
    data = base * scales
    p = aqua.compute_projection(data)
    proj = data @ p
    var = jnp.var(proj, axis=0)
    assert np.all(np.diff(np.asarray(var)) <= 1e-3), var


def test_gqa_calibration_matrix_shape():
    q = jnp.ones((4, 100, 32))
    k = jnp.ones((100, 32))
    d = aqua.gqa_calibration_matrix(q, k)
    assert d.shape == (5 * 100, 32)


# ---------------------------------------------------------------------------
# rotation invariance (paper Lemma A.4) — property test
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.sampled_from([8, 16, 32]),
       s=st.integers(1, 32))
def test_rotation_invariance_of_scores(seed, d, s):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (1, d))
    kc = jax.random.normal(k2, (s, d))
    p = random_orthogonal(k3, d)
    s_orig = q @ kc.T
    s_proj = (q @ p) @ (kc @ p).T
    np.testing.assert_allclose(s_proj, s_orig, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# magnitude selection (paper §7)
# ---------------------------------------------------------------------------


def test_magnitude_mask_selects_largest():
    q = jnp.array([[0.1, -5.0, 0.2, 3.0, -0.05, 1.0, 0.0, -2.0]])
    m = aqua.magnitude_mask(q, 3)
    np.testing.assert_array_equal(
        np.asarray(m[0]), [0, 1, 0, 1, 0, 0, 0, 1])


def test_magnitude_mask_full_keep():
    q = jnp.ones((2, 8))
    m = aqua.magnitude_mask(q, 8)
    assert np.all(np.asarray(m) == 1)


def test_magnitude_mask_block_granularity():
    q = jnp.array([[10.0, 10.0, 0.0, 0.0, 0.1, 0.1, 5.0, 5.0]])
    m = aqua.magnitude_mask(q, 4, block_dims=2)
    # blocks: [20, 0, 0.2, 10] -> top2 = blocks 0 and 3
    np.testing.assert_array_equal(np.asarray(m[0]), [1, 1, 0, 0, 0, 0, 1, 1])


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       frac=st.sampled_from([0.25, 0.5, 0.75]))
def test_magnitude_beats_or_matches_slicing(seed, frac):
    """Paper Fig. 2: top-k-by-magnitude retains >= energy of naive slicing
    (holds pointwise by definition of top-k on any vector)."""
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, (16, 64))
    k_dims = int(64 * frac)
    m_mag = aqua.magnitude_mask(v, k_dims)
    m_slice = aqua.slicing_mask(64, k_dims, v)
    l_mag = aqua.info_retention_loss(v, v, m_mag)
    l_slice = aqua.info_retention_loss(v, v, m_slice)
    assert np.all(np.asarray(l_mag) <= np.asarray(l_slice) + 1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_approx_scores_exact_at_full_ratio(seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    q = jax.random.normal(k1, (4, 32))
    kc = jax.random.normal(k2, (4, 16, 32))
    mask = jnp.ones_like(q)
    s = aqua.approx_scores(q, kc, mask)
    ref = jnp.einsum("bd,bsd->bs", q, kc)
    np.testing.assert_allclose(s, ref, rtol=1e-5, atol=1e-5)


def test_topk_block_indices_sorted_and_valid():
    q = jax.random.normal(jax.random.PRNGKey(3), (2, 4, 64))
    idx = aqua.topk_block_indices(q, 32, 8)
    assert idx.shape == (2, 4, 4)
    a = np.asarray(idx)
    assert np.all(np.diff(a, axis=-1) > 0)
    assert a.min() >= 0 and a.max() < 8


# ---------------------------------------------------------------------------
# info retention loss metric (paper §6.2)
# ---------------------------------------------------------------------------


def test_info_loss_zero_when_nothing_dropped():
    key = jax.random.PRNGKey(5)
    v = jax.random.normal(key, (8, 32))
    p = random_orthogonal(jax.random.PRNGKey(6), 32)
    l = aqua.info_retention_loss(v, v @ p, jnp.ones((8, 32)))
    np.testing.assert_allclose(np.asarray(l), 0.0, atol=1e-4)


def test_info_loss_monotone_in_kept_dims():
    v = jax.random.normal(jax.random.PRNGKey(7), (32, 64))
    losses = []
    for k_dims in (8, 16, 32, 48, 64):
        m = aqua.magnitude_mask(v, k_dims)
        losses.append(float(aqua.info_retention_loss(v, v, m).mean()))
    assert all(a >= b - 1e-6 for a, b in zip(losses, losses[1:])), losses


# ---------------------------------------------------------------------------
# weight folding
# ---------------------------------------------------------------------------


def test_fold_projection_matches_runtime_projection():
    key = jax.random.PRNGKey(8)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    wq = jax.random.normal(k1, (32, 16))
    wk = jax.random.normal(k2, (32, 16))
    p = random_orthogonal(k3, 16)
    x = jax.random.normal(k4, (5, 32))
    fq, fk = aqua.fold_projection_into_weights(wq, wk, p)
    np.testing.assert_allclose((x @ wq) @ p, x @ fq, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose((x @ wk) @ p, x @ fk, rtol=1e-4, atol=1e-4)


def test_aqua_config_ratios():
    from repro.configs.base import AquaConfig
    c = AquaConfig(k_ratio=0.75, s_ratio=0.25)
    assert abs(c.e_ratio - 0.5625) < 1e-9
    assert c.kept_dims(128) == 96
    assert c.topk_dims(128) == 72
    c8 = AquaConfig(k_ratio=0.75, block_dims=8)
    assert c8.topk_dims(128) % 8 == 0
