"""End-to-end behaviour tests: training converges, calibrate -> serve
pipeline works, AQUA degrades gracefully (paper Table 1 direction)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.configs.base import AquaConfig, TrainConfig
from repro.core.calibration import calibrate, save_projections, \
    load_projections
from repro.data.pipeline import (DataConfig, calibration_batches, make_batch)
from repro.launch.train import Trainer
from repro.models import build_model
from repro.serving import ServeEngine


@pytest.fixture(scope="module")
def trained():
    """Train a tiny qwen3-family model on the learnable LCG language."""
    cfg = dataclasses.replace(reduced("qwen3-0.6b", vocab=64), remat=False)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=16)
    trainer = Trainer(cfg, tcfg, dcfg, donate=False)
    state, losses = trainer.run(60, log_every=1000)
    return cfg, state.params, losses, dcfg


def test_training_converges(trained):
    _, _, losses, _ = trained
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first * 0.7, (first, last)


def test_calibration_pipeline(trained, tmp_path):
    cfg, params, _, _ = trained
    model = build_model(cfg)

    def fwd_cap(p, batch):
        _, aux = model.forward(p, batch, capture=True)
        return aux
    proj = calibrate(fwd_cap, params,
                     calibration_batches(cfg, num_batches=2, batch=2, seq=32),
                     cfg)
    acfg = cfg.attention
    assert proj.p.shape == (cfg.num_layers, acfg.num_kv_heads,
                            acfg.head_dim, acfg.head_dim)
    # every projection is orthogonal (paper Lemma A.4 precondition)
    eye = np.eye(acfg.head_dim)
    for li in range(cfg.num_layers):
        for h in range(acfg.num_kv_heads):
            p = np.asarray(proj.p[li, h])
            np.testing.assert_allclose(p @ p.T, eye, atol=1e-3)
    # save/load roundtrip
    path = str(tmp_path / "proj.npz")
    save_projections(path, proj)
    p2 = load_projections(path)
    np.testing.assert_array_equal(np.asarray(proj.p), np.asarray(p2.p))


def test_aqua_graceful_degradation(trained):
    """Paper Table 1 direction: NLL(k=1.0) <= NLL(0.75) <= NLL(0.3)+slack,
    and k=1.0 with calibrated P equals the no-AQUA baseline."""
    cfg, params, _, dcfg = trained
    model = build_model(cfg)

    def fwd_cap(p, batch):
        _, aux = model.forward(p, batch, capture=True)
        return aux
    proj = calibrate(fwd_cap, params,
                     calibration_batches(cfg, num_batches=2, batch=2, seq=32),
                     cfg)
    eval_batch = make_batch(dcfg, step=10_001)

    nlls = {}
    for kr in (1.0, 0.75, 0.3):
        c = dataclasses.replace(cfg, aqua=AquaConfig(k_ratio=kr,
                                                     block_dims=1))
        eng = ServeEngine(c, params, proj, max_seq=64)
        nlls[kr] = float(eng.score(eval_batch))
    base_eng = ServeEngine(cfg, params, None, max_seq=64)
    base = float(base_eng.score(eval_batch))
    # rotation invariance: full ratio == baseline
    np.testing.assert_allclose(nlls[1.0], base, rtol=5e-2, atol=5e-2)
    # graceful degradation direction
    assert nlls[0.75] <= nlls[0.3] + 1e-3, nlls
    assert nlls[1.0] <= nlls[0.75] + 0.1, nlls


def test_generate_greedy_deterministic(trained):
    cfg, params, _, _ = trained
    eng = ServeEngine(cfg, params, None, max_seq=64)
    batch = {"tokens": jnp.arange(8, dtype=jnp.int32)[None].repeat(2, 0)}
    r1 = eng.generate(batch, steps=5)
    r2 = eng.generate(batch, steps=5)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r1.tokens.shape == (2, 5)


def test_trained_model_predicts_lcg(trained):
    """The LCG language is deterministic; a converged model should often
    predict the next token exactly."""
    cfg, params, _, dcfg = trained
    model = build_model(cfg)
    batch = make_batch(dcfg, step=999)
    logits = model.forward(params, batch)
    pred = np.asarray(jnp.argmax(logits, -1))
    gold = np.asarray(batch["labels"])
    acc = (pred[:, 8:] == gold[:, 8:]).mean()  # skip warm-up positions
    assert acc > 0.35, acc


def test_aqua_memory_reduces_cache(trained):
    cfg, params, _, _ = trained
    from repro.core.calibration import identity_projections
    proj = identity_projections(cfg.num_layers, cfg.attention.num_kv_heads,
                                cfg.attention.head_dim)
    base = ServeEngine(cfg, params, None, max_seq=64).cache_bytes(4)
    c_mem = dataclasses.replace(
        cfg, aqua=AquaConfig(k_ratio=1.0, s_ratio=0.25, block_dims=1))
    small = ServeEngine(c_mem, params, proj, max_seq=64).cache_bytes(4)
    assert small < base, (small, base)
