"""Attention core: chunked==dense, AQUA prefill/decode equivalences,
cache-building correctness."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs.base import AquaConfig, AttentionConfig
from repro.core import attention as A
from repro.core import kvcache as kv
from repro.core.calibration import identity_projections


def _params(acfg, d_model=32, seed=0):
    return A.init_attention_params(jax.random.PRNGKey(seed), d_model, acfg)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       window=st.sampled_from([None, 8, 24]))
def test_chunked_equals_dense(seed, window):
    b, s, kvh, g, d = 1, 32, 2, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, kvh, g, d))
    k = jax.random.normal(ks[1], (b, s, kvh, d))
    v = jax.random.normal(ks[2], (b, s, kvh, d))
    out = A.chunked_attention(q, k, v, head_dim=d, causal=True,
                              window=window, q_blk=8, k_blk=16)
    sc = jnp.einsum("bskgd,btkd->bkgst", q, k) / np.sqrt(d)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = qp >= kp
    if window is not None:
        mask &= kp > qp - window
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    ref = jnp.einsum("bkgst,btkd->bskgd", jax.nn.softmax(sc, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_prefill_uses_chunked_path_same_result():
    """Force the chunked threshold boundary: results identical either side."""
    acfg = AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=8)
    p = _params(acfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    dense = A.prefill_attention(p, x, acfg)
    old = A.CHUNKED_THRESHOLD
    try:
        A.CHUNKED_THRESHOLD = 32
        chunked = A.prefill_attention(p, x, acfg)
    finally:
        A.CHUNKED_THRESHOLD = old
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               rtol=1e-4, atol=1e-4)


def test_aqua_full_ratio_equals_standard():
    """k_ratio=1 with an orthogonal P must equal exact attention
    (paper Lemma A.4: projection is a lossless rotation)."""
    acfg = AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16)
    p = _params(acfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 12, 32))
    m = jax.random.normal(jax.random.PRNGKey(3), (16, 16))
    qmat, _ = jnp.linalg.qr(m)
    proj = jnp.broadcast_to(qmat, (2, 16, 16))
    aqua = AquaConfig(k_ratio=1.0, block_dims=1)
    out_std = A.prefill_attention(p, x, acfg)
    out_aqua = A.prefill_attention(p, x, acfg, aqua, proj)
    np.testing.assert_allclose(np.asarray(out_aqua), np.asarray(out_std),
                               rtol=1e-3, atol=1e-3)


def test_aqua_identity_proj_partial_ratio_changes_little():
    acfg = AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16)
    p = _params(acfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 12, 32)) * 0.5
    proj = identity_projections(1, 2, 16).p[0]
    out_std = A.prefill_attention(p, x, acfg)
    out_aqua = A.prefill_attention(
        p, x, acfg, AquaConfig(k_ratio=0.75, block_dims=1), proj)
    # approximation error bounded (not zero, not huge)
    err = np.abs(np.asarray(out_aqua - out_std)).max()
    assert 0.0 < err < 2.0


def test_build_cache_matches_decode_inserts():
    """Prefill-built cache must equal the cache produced by stepwise
    decode inserts (full-cache policy)."""
    acfg = AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=8)
    d_model = 16
    p = _params(acfg, d_model)
    s = 6
    x = jax.random.normal(jax.random.PRNGKey(5), (1, s, d_model))
    cache_pre = A.build_cache_from_prefill(p, x, acfg, None, None, max_seq=8)

    cache_step = kv.init_attn_cache(1, 2, 8, 8, 8, jnp.float32)
    for t in range(s):
        _, cache_step = A.decode_attention(p, x[:, t], cache_step, acfg)
    np.testing.assert_allclose(np.asarray(cache_pre.k[:, :, :s]),
                               np.asarray(cache_step.k[:, :, :s]),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cache_pre.v[:, :, :s]),
                               np.asarray(cache_step.v[:, :, :s]),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(cache_pre.positions[:, :s]),
                                  np.asarray(cache_step.positions[:, :s]))


def test_rope_positions():
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 4, 2, 8))
    r0 = A.rope(x, jnp.arange(4), 10000.0)
    assert r0.shape == x.shape
    # position 0 is identity
    np.testing.assert_allclose(np.asarray(r0[:, 0]), np.asarray(x[:, 0]),
                               rtol=1e-6)
    # rope preserves norms
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(r0), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)


def test_qk_norm_and_bias_paths():
    acfg = AttentionConfig(num_heads=2, num_kv_heads=1, head_dim=8,
                           qk_norm=True, qkv_bias=True)
    p = _params(acfg)
    assert "q_norm" in p and "bq" in p
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 4, 32))
    out = A.prefill_attention(p, x, acfg)
    assert np.isfinite(np.asarray(out)).all()
