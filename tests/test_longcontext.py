"""Long-context hierarchical AQUA: needle retrieval through the
two-stage (page-granular × dim-block) pipeline.

Ranking level at the true 32k geometry (256 pages of 128): a needle page
deep in the context whose H2O mass dominates must rank into a 32-page
keep set, while zeroed statistics degrade deterministically to
attention-sink + pinned recent tail and drop it.

Kernel level at a reduced long geometry: the hierarchical Pallas decode
kernel retrieves the needle's value when its page participates, misses it
when stage 1 drops the page, and a full participation table is
bit-identical to the plain paged kernel (`page_keep_ratio=1.0` is the
identity, not an approximation). The prefill analogue checks an identity
q-tile participation table against the monolithic kernel.

Engine level: `SparsitySpec(page_keep_ratio=1.0)` resolves to no token
sparsity at all — same plan, same tokens as an engine without the spec.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.configs.base import (AquaConfig, CacheSpec, ServingConfig,
                                SparsitySpec)
from repro.core import selection
from repro.core.calibration import identity_projections
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, Request

PS = 128


def _paged_pools(khat, v):
    """Contiguous (B=1, KV, S, D) -> identity-table page pools."""
    kvh, s, d = khat.shape[1:]
    npg = s // PS
    pool_k = khat[0].reshape(kvh, npg, PS, d).transpose(1, 0, 2, 3)
    pool_v = v[0].reshape(kvh, npg, PS, d).transpose(1, 0, 2, 3)
    table = jnp.arange(npg, dtype=jnp.int32)[None]
    return pool_k, pool_v, table


# ---------------------------------------------------------------------------
# Ranking level: 32k context, 256 pages
# ---------------------------------------------------------------------------


def test_needle_page_ranks_in_at_32k():
    s, kvh = 32768, 2
    npl = s // PS
    kept = SparsitySpec(page_keep_ratio=0.125).kept_pages(npl)
    assert kept == 32
    acc = jnp.zeros((npl, kvh, PS), jnp.float32).at[77].set(1.0)
    table = jnp.arange(npl, dtype=jnp.int32)[None]
    count = jnp.full((1,), s, jnp.int32)
    part = np.asarray(selection.participating_pages(
        acc, table, count, page_size=PS, kept_pages=kept,
        pin_recent_pages=2))[0]
    assert 77 in part, part
    assert npl - 1 in part and npl - 2 in part          # recency pin
    assert (np.sort(part) == part).all()
    # the numpy --verify oracle agrees at this geometry
    ref = selection.reference_participating_pages(
        acc, table, count, page_size=PS, kept_pages=kept,
        pin_recent_pages=2)
    np.testing.assert_array_equal(part, ref[0])


def test_zero_stats_degrade_to_sink_plus_pinned_tail():
    """A cache with no H2O mass (hierarchical serving keeps h2o off) must
    rank deterministically: earliest pages (attention sink, lowest-index
    tie-break) plus the pinned recent pages — never arbitrary."""
    s, kvh = 32768, 2
    npl = s // PS
    acc = jnp.zeros((npl, kvh, PS), jnp.float32)
    table = jnp.arange(npl, dtype=jnp.int32)[None]
    count = jnp.full((1,), s, jnp.int32)
    part = np.asarray(selection.participating_pages(
        acc, table, count, page_size=PS, kept_pages=32,
        pin_recent_pages=2))[0]
    expect = np.sort(np.concatenate([np.arange(30), [npl - 2, npl - 1]]))
    np.testing.assert_array_equal(part, expect)


# ---------------------------------------------------------------------------
# Kernel level: reduced long geometry (1024 tokens, 8 pages)
# ---------------------------------------------------------------------------


def _needle_setup():
    b, h, kvh, s, d = 1, 4, 2, 1024, 64
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    # one query direction shared by every head, so a single needle key
    # dominates all of them (logit ~ 3·|q|²/√d ≫ background)
    qvec = jax.random.normal(ks[0], (d,))
    q = jnp.broadcast_to(qvec, (b, h, d))
    khat = jax.random.normal(ks[1], (b, kvh, s, d))
    v = jax.random.normal(ks[2], (b, kvh, s, d))
    # plant the needle mid-context (page 3) with a recognizable value
    needle = 3 * PS + 5
    khat = khat.at[0, :, needle].set(3.0 * qvec)
    v = v.at[0, :, needle, :].set(5.0)
    lengths = jnp.full((b,), s, jnp.int32)
    return q, khat, v, lengths


def test_hier_kernel_retrieves_needle_when_mass_ranks_it_in():
    from repro.kernels.ops import aqua_paged_decode
    q, khat, v, lengths = _needle_setup()
    pool_k, pool_v, table = _paged_pools(khat, v)
    npl = pool_k.shape[0]
    acc = jnp.zeros((npl, 2, PS), jnp.float32).at[3].set(1.0)
    part = selection.participating_pages(
        acc, table, lengths, page_size=PS, kept_pages=4,
        pin_recent_pages=2)
    assert 3 in np.asarray(part)[0]
    out = aqua_paged_decode(q, pool_k, pool_v, table, lengths,
                            part_idx=part, k_ratio=1.0, block_dims=8,
                            seq_blk=PS)
    # softmax is dominated by the needle -> output pulled to its value
    assert float(jnp.max(jnp.abs(out - 5.0))) < 0.5, out


def test_hier_kernel_misses_needle_when_page_dropped():
    from repro.kernels.ops import aqua_paged_decode
    q, khat, v, lengths = _needle_setup()
    pool_k, pool_v, table = _paged_pools(khat, v)
    npl = pool_k.shape[0]
    acc = jnp.zeros((npl, 2, PS), jnp.float32)          # no mass anywhere
    part = selection.participating_pages(
        acc, table, lengths, page_size=PS, kept_pages=4,
        pin_recent_pages=2)
    assert 3 not in np.asarray(part)[0]                 # sink + tail only
    out = aqua_paged_decode(q, pool_k, pool_v, table, lengths,
                            part_idx=part, k_ratio=1.0, block_dims=8,
                            seq_blk=PS)
    # the needle's value never streams: output stays near the background
    assert float(jnp.max(jnp.abs(out - 5.0))) > 2.0, out


def test_full_participation_bit_identical_to_paged_kernel():
    from repro.kernels.ops import aqua_paged_decode
    q, khat, v, lengths = _needle_setup()
    pool_k, pool_v, table = _paged_pools(khat, v)
    npl = pool_k.shape[0]
    ident = jnp.arange(npl, dtype=jnp.int32)[None]
    for kr in (0.5, 1.0):
        out_h = aqua_paged_decode(q, pool_k, pool_v, table, lengths,
                                  part_idx=ident, k_ratio=kr,
                                  block_dims=8, seq_blk=PS)
        out_p = aqua_paged_decode(q, pool_k, pool_v, table, lengths,
                                  k_ratio=kr, block_dims=8, seq_blk=PS)
        np.testing.assert_array_equal(np.asarray(out_h), np.asarray(out_p))


def test_prefill_identity_tile_table_bit_identical():
    """An identity q-tile participation table walks the same tiles in the
    same order as the monolithic prefill kernel — bit-identical."""
    from repro.core.aqua import chunk_topk_block_indices
    from repro.kernels.aqua_prefill import aqua_prefill_attention
    from repro.kernels.ops import aqua_prefill, round_k_dims, \
        to_dim_major_blocks
    b, h, kvh, s, d = 1, 2, 2, 512, 32
    q_blk = k_blk = 128
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    khat = jax.random.normal(ks[1], (b, kvh, s, d))
    v = jax.random.normal(ks[2], (b, kvh, s, d))
    lengths = jnp.full((b,), s, jnp.int32)
    ref = aqua_prefill(q, khat, v, lengths, k_ratio=0.5, block_dims=8,
                       q_blk=q_blk, k_blk=k_blk)

    nqc, nkc = s // q_blk, s // k_blk
    nb = d // 8
    k_dims = round_k_dims(d, 0.5, 8)
    block_idx = chunk_topk_block_indices(q, k_dims, 8, q_blk, lengths)
    qb = q.reshape(b, h, nqc, q_blk, nb, 8).transpose(0, 1, 2, 4, 3, 5)
    q_sel = jnp.take_along_axis(qb, block_idx[..., None, None], axis=3)
    kc_part = jnp.broadcast_to(jnp.arange(nkc, dtype=jnp.int32),
                               (b, nqc, nkc))
    out = aqua_prefill_attention(q_sel, to_dim_major_blocks(khat, 8), v,
                                 block_idx, lengths, kc_part,
                                 block_dims=8, q_blk=q_blk, k_blk=k_blk,
                                 causal=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# Engine level: page_keep_ratio=1.0 is the identity configuration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def block_sparse_model():
    cfg = dataclasses.replace(reduced("qwen3-0.6b"), remat=False,
                              dtype="float32",
                              aqua=AquaConfig(k_ratio=0.5, block_dims=8))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    proj = identity_projections(cfg.num_layers, cfg.attention.num_kv_heads,
                                cfg.attention.head_dim)
    return cfg, params, proj


def _trace(cfg, n=4, max_new=6):
    rng = np.random.default_rng(3)
    return [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab_size, size=(12,),
                                        dtype=np.int32),
                    max_new_tokens=max_new, arrival=float(i))
            for i in range(n)]


def test_keep_ratio_one_is_engine_identity(block_sparse_model):
    cfg, params, proj = block_sparse_model
    scfg = ServingConfig(max_lanes=2, max_seq=32, max_new_tokens=6,
                         prompt_bucket=8,
                         cache=CacheSpec(page_size=8, num_pages=10))
    reqs = _trace(cfg)
    base = ContinuousBatchingEngine(cfg, params, proj, serving=scfg,
                                    backend="aqua-block-sparse").run(reqs)
    full = dataclasses.replace(scfg,
                               sparsity=SparsitySpec(page_keep_ratio=1.0))
    eng = ContinuousBatchingEngine(cfg, params, proj, serving=full,
                                   backend="aqua-block-sparse")
    assert eng.dispatch_plan().token_sparsity == "none"
    assert eng.kept_pages is None
    out = eng.run(reqs)
    for uid in base:
        assert list(base[uid].tokens) == list(out[uid].tokens), uid


def test_hierarchical_engine_serves_and_drops_pages(block_sparse_model):
    """A ratio below 1.0 on a paged engine plans hierarchical token
    sparsity, resolves a kept-page budget below the lane page count, and
    still serves every request to completion."""
    cfg, params, proj = block_sparse_model
    scfg = ServingConfig(max_lanes=2, max_seq=64, max_new_tokens=8,
                         prompt_bucket=8,
                         cache=CacheSpec(page_size=8, num_pages=18),
                         sparsity=SparsitySpec(page_keep_ratio=0.5))
    eng = ContinuousBatchingEngine(cfg, params, proj, serving=scfg,
                                   backend="aqua-block-sparse")
    plan = eng.dispatch_plan()
    assert plan.token_sparsity == "hierarchical", plan
    assert eng.kept_pages == 4                           # 0.5 × 8 pages
    rng = np.random.default_rng(9)
    reqs = [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab_size, size=(30,),
                                        dtype=np.int32),
                    max_new_tokens=8, arrival=float(i)) for i in range(3)]
    out = eng.run(reqs)
    assert all(len(o.tokens) == 8 for o in out.values())


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
