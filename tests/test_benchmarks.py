"""Golden-shape / finiteness tests for the benchmark entry points.

``benchmarks/fidelity.py`` and ``benchmarks/roofline.py`` were previously
exercised only by the CI smoke (which just checks the process exits 0);
these tests assert on the rows themselves: every derived metric parses and
is finite, the paged-cache HBM/bytes rows exist with the expected values,
and the roofline renderer produces the golden table shape.
"""

import json
import math

import pytest

from benchmarks import roofline
from benchmarks.compare import _metrics
from repro.configs import SHAPES, get_config


def _assert_rows(rows, prefix):
    assert rows, f"{prefix}: no rows"
    for name, us, derived in rows:
        assert name.startswith(prefix), name
        assert isinstance(derived, str) and derived, name
        assert math.isfinite(us) and us >= 0.0, (name, us)
        for metric, value in _metrics(derived).items():
            assert math.isfinite(value), (name, metric, value)
    return {name: _metrics(derived) for name, _, derived in rows}


def test_breakeven_rows():
    from benchmarks.fidelity import breakeven

    metrics = _assert_rows(breakeven(), "breakeven/")
    assert "breakeven/folded_projection" in metrics
    row = metrics["breakeven/d128_k64"]
    assert row["exact_tokens"] == 2 * row["paper_O_tokens"]


def test_kernel_bandwidth_rows_include_paged():
    from benchmarks.fidelity import kernel_bandwidth

    metrics = _assert_rows(kernel_bandwidth(), "kernel/")
    assert metrics["kernel/dense_ref"]["hbm_bytes_ratio"] == 1.0
    for kr in (0.5, 0.75):
        contiguous = metrics[f"kernel/aqua_decode_k{kr}"]
        paged = metrics[f"kernel/aqua_paged_decode_k{kr}"]
        # pages only redirect addressing: same score-byte ratio, and the
        # paged kernel must agree with the contiguous kernel numerically
        assert paged["hbm_bytes_ratio"] == contiguous["hbm_bytes_ratio"]
        assert paged["hbm_bytes_ratio"] < 1.0
        assert paged["max_abs_err"] <= 1e-5


def test_prefill_backend_rows():
    from benchmarks.fidelity import prefill_backends

    metrics = _assert_rows(prefill_backends(), "prefill/")
    assert metrics["prefill/flash_vs_dense"]["max_abs_err"] < 1e-3
    for kr in (0.5, 0.75, 1.0):
        row = metrics[f"prefill/aqua_block_sparse_k{kr}"]
        assert row["max_abs_err"] < 1e-3
        assert 0.0 < row["score_bytes_ratio"] <= 1.0


def test_roofline_model_flops_finite():
    for arch in ("qwen3-0.6b", "qwen2-moe-a2.7b", "whisper-tiny"):
        cfg = get_config(arch)
        n = roofline.active_params(cfg)
        assert math.isfinite(n) and n > 0, arch
        for shape in SHAPES:
            f = roofline.model_flops(cfg, shape, chips=16)
            assert math.isfinite(f) and f > 0, (arch, shape.name)


def test_roofline_render_golden(tmp_path):
    records = [
        {
            "arch": "qwen3-0.6b",
            "shape": "decode_32k",
            "chips": 16,
            "t_compute_s": 1e-3,
            "t_memory_s": 2e-3,
            "t_collective_s": 5e-4,
            "bottleneck": "memory",
            "hlo_flops": 1e12,
        },
        {"arch": "llama31-8b", "shape": "train_4k", "skipped": "oom"},
        {"arch": "mamba2-370m", "shape": "long_500k", "error": "boom"},
    ]
    path = tmp_path / "roofline.jsonl"
    path.write_text("\n".join(json.dumps(r) for r in records) + "\n")
    table = roofline.render(str(path))
    lines = table.splitlines()
    assert lines[0].startswith("| arch | shape |")
    assert len(lines) == 2 + len(records)  # header + separator + rows
    assert "HBM-bound" in table  # memory-bottleneck recommendation
    assert "skipped" in lines[3] and "ERROR" in lines[4]


@pytest.mark.slow
def test_quality_sweep_rows_golden():
    """Golden shape/finiteness for the k_ratio quality sweep (needs the
    cached trained bench model — nightly-slow; the HF-ingestion quality
    rows have a fast equivalent in tests/test_quality.py)."""
    from benchmarks.quality import quality_sweep

    metrics = _assert_rows(quality_sweep(), "quality/")
    exact = metrics["quality/exact"]
    assert exact["ppl"] >= 1.0 and 0.0 <= exact["acc"] <= 1.0
    for k in ("1", "0.75", "0.5"):
        row = metrics[f"quality/aqua_k{k}"]
        assert row["ppl"] >= exact["ppl"] * (1 - 1e-4), (k, row)
        assert 0.0 <= row["token_match"] <= 1.0
    # full-kept rotation: same quality, same greedy tokens
    assert metrics["quality/aqua_k1"]["ppl"] == \
        pytest.approx(exact["ppl"], rel=1e-3)
    assert metrics["quality/aqua_k1"]["token_match"] == 1.0
    # composition rows (int8 pools / hierarchical pages) exist and carry
    # the greedy-agreement contract metric
    assert "token_match" in metrics["quality/aqua_k0.5+int8"]
    assert "token_match" in metrics["quality/aqua_k0.5+hier"]


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
