"""Mamba-2 SSD and RG-LRU numerics: chunked/associative-scan forms vs
sequential step oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import ssd_chunked, ssd_step
from repro.models.rglru import rglru_scan, rglru_step


@pytest.mark.parametrize("chunk", [4, 8, 16])
@pytest.mark.parametrize("s", [16, 24])  # 24 exercises padding
def test_ssd_chunked_matches_sequential(chunk, s):
    b, h, p, g, n = 2, 4, 8, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    bb = jax.random.normal(ks[3], (b, s, g, n))
    cc = jax.random.normal(ks[4], (b, s, g, n))
    d_skip = jnp.ones((h,)) * 0.5

    y, final = ssd_chunked(x, dt, a_log, bb, cc, d_skip, chunk)

    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        yt, state = ssd_step(state, x[:, t], dt[:, t], a_log,
                             bb[:, t], cc[:, t], d_skip)
        ys.append(yt)
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(state),
                               rtol=2e-4, atol=2e-4)


def test_ssd_multi_group():
    b, s, h, p, g, n = 1, 8, 4, 4, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.1)
    bb = jax.random.normal(ks[3], (b, s, g, n))
    cc = jax.random.normal(ks[4], (b, s, g, n))
    d_skip = jnp.zeros((h,))
    y, final = ssd_chunked(x, dt, a_log, bb, cc, d_skip, 4)
    state = jnp.zeros((b, h, p, n))
    for t in range(s):
        yt, state = ssd_step(state, x[:, t], dt[:, t], a_log, bb[:, t],
                             cc[:, t], d_skip)
    np.testing.assert_allclose(np.asarray(y[:, -1]), np.asarray(yt),
                               rtol=2e-4, atol=2e-4)


def test_rglru_scan_matches_sequential():
    b, s, w = 2, 12, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(ks[0], (b, s, w))
    r = jax.nn.sigmoid(jax.random.normal(ks[1], (b, s, w)))
    i_g = jax.nn.sigmoid(jax.random.normal(ks[2], (b, s, w)))
    lam = jax.random.normal(ks[3], (w,))

    hs, h_last = rglru_scan(x, r, i_g, lam)
    h = jnp.zeros((b, w))
    for t in range(s):
        h, _ = rglru_step(x[:, t], r[:, t], i_g[:, t], lam, h)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hs[:, -1]), np.asarray(h),
                               rtol=1e-5, atol=1e-5)


def test_rglru_scan_initial_state():
    b, s, w = 1, 6, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (b, s, w))
    r = jax.nn.sigmoid(jax.random.normal(ks[1], (b, s, w)))
    i_g = jax.nn.sigmoid(jax.random.normal(ks[2], (b, s, w)))
    lam = jax.random.normal(ks[3], (w,))
    h0 = jax.random.normal(ks[4], (b, w))
    hs, _ = rglru_scan(x, r, i_g, lam, h0=h0)
    h = h0
    for t in range(s):
        h, _ = rglru_step(x[:, t], r[:, t], i_g[:, t], lam, h)
    np.testing.assert_allclose(np.asarray(hs[:, -1]), np.asarray(h),
                               rtol=1e-5, atol=1e-5)


def test_rglru_stability():
    """|a_t| <= 1 -> bounded state for bounded input."""
    b, s, w = 1, 200, 4
    x = jnp.ones((b, s, w))
    r = jnp.ones((b, s, w)) * 0.9
    i_g = jnp.ones((b, s, w))
    lam = jnp.ones((w,)) * 2.0
    hs, _ = rglru_scan(x, r, i_g, lam)
    assert np.isfinite(np.asarray(hs)).all()
    assert np.abs(np.asarray(hs)).max() < 100.0
