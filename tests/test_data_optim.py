"""Data pipeline determinism + optimizer + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import TrainConfig
from repro.data.pipeline import DataConfig, lcg_batch, make_batch, \
    uniform_batch
from repro.optim import adamw, compress
from repro.optim.schedule import cosine_with_warmup


def test_data_deterministic_by_index():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    b1 = make_batch(cfg, 7)
    b2 = make_batch(cfg, 7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = make_batch(cfg, 8)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_lcg_batch_is_learnable_structure():
    cfg = DataConfig(vocab_size=50, seq_len=20, global_batch=3)
    b = lcg_batch(cfg, 0)
    t = np.asarray(b["tokens"])
    l = np.asarray(b["labels"])
    # labels are the shifted sequence
    np.testing.assert_array_equal(t[:, 1:], l[:, :-1])
    # sequence follows an affine rule: differences of consecutive recurrences
    # are consistent (x2-x1 == a*(x1-x0) mod V for the same row)
    assert t.min() >= 0 and t.max() < 50


def test_uniform_batch_range():
    cfg = DataConfig(vocab_size=11, seq_len=8, global_batch=2,
                     kind="uniform")
    b = uniform_batch(cfg, 0)
    assert np.asarray(b["tokens"]).max() < 11


def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    state = adamw.init(params)
    cfg = TrainConfig(learning_rate=0.1, weight_decay=0.0, grad_clip=10.0)
    for _ in range(100):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = adamw.update(params, grads, state, 0.1, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clip():
    g = {"a": jnp.ones((10,)) * 100.0}
    clipped, gn = adamw.clip_by_global_norm(g, 1.0)
    assert float(adamw.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(gn) > 100.0


def test_schedule_warmup_and_decay():
    cfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    assert float(cosine_with_warmup(0, cfg)) == 0.0
    assert abs(float(cosine_with_warmup(10, cfg)) - 1e-3) < 1e-9
    assert float(cosine_with_warmup(100, cfg)) < 1e-5
    assert float(cosine_with_warmup(5, cfg)) == pytest.approx(5e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_int8_quantize_roundtrip_bounded(seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 10
    q, s = compress.quantize(g)
    deq = compress.dequantize(q, s)
    assert q.dtype == jnp.int8
    # error bounded by half a quantization step
    assert float(jnp.abs(deq - g).max()) <= float(s) * 0.51 + 1e-6


def test_error_feedback_accumulates_signal():
    """With error feedback, the accumulated dequantized sum converges to the
    accumulated true gradient (unbiased over steps)."""
    true_g = jnp.full((32,), 0.001)  # tiny gradient, below 1 quant step
    err = jnp.zeros((32,))
    total = jnp.zeros((32,))
    for _ in range(200):
        gp = true_g + err
        q, s = compress.quantize(gp)
        deq = compress.dequantize(q, s)
        err = gp - deq
        total = total + deq
    np.testing.assert_allclose(np.asarray(total),
                               np.asarray(true_g * 200), rtol=0.05)


def test_microbatch_equivalence():
    """Gradient accumulation over 2 microbatches == single large batch."""
    import dataclasses
    from repro.configs import reduced
    from repro.launch.train import TrainState, make_train_step
    from repro.data.pipeline import DataConfig, make_batch
    from repro.models import build_model
    cfg = dataclasses.replace(reduced("qwen3-0.6b"), remat=False,
                              num_layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=8, global_batch=4)
    batch = make_batch(dcfg, 0)

    def run(mb):
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1,
                           total_steps=10, microbatches=mb)
        st = TrainState(params=params, opt=adamw.init(params),
                        step=jnp.zeros((), jnp.int32))
        fn = jax.jit(make_train_step(model, tcfg))
        st, metrics = fn(st, batch)
        return st, metrics
    s1, m1 = run(1)
    s2, m2 = run(2)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
