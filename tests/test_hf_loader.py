"""Oracle tests for the HF safetensors ingestion path (checkpoint.hf).

The fixture generator (tests/hf_fixtures.py -> repro.checkpoint.fixtures)
writes tiny random qwen3-geometry checkpoints in *genuine* HF layout
(config.json + safetensors, single-file and sharded-index variants), so
every mapping spec is exercised bit-exactly with zero network. The GQA
head reshapes are pinned against an independent numpy einsum oracle of
the HF attention semantics (query head h = kv*G + g reads the h-th D-row
block — the repeat_kv convention), not against the loader's own code.
"""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

from hf_fixtures import QWEN3_TINY, make_fixture, write_hf_fixture
from repro.checkpoint.hf import (TRANSFORMS, config_from_hf,
                                 load_hf_checkpoint, mapping_specs,
                                 resolve_tensor_files)
from repro.checkpoint.manager import CheckpointManager
from repro.models import build_model


def _paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {
        "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p): v
        for p, v in flat
    }


def _leaf(params, spec):
    node = _paths(params)["/".join(spec.path)]
    return np.asarray(node if spec.layer is None else node[spec.layer])


# -- mapping-spec coverage + bit-exact round-trip ---------------------------


@pytest.mark.parametrize("tied", [False, True])
def test_specs_cover_init_tree(tmp_path, tied):
    _, cfg, _ = make_fixture(tmp_path, tied=tied)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    want = set(_paths(params))
    specs = mapping_specs(cfg)
    got = {"/".join(s.path) for s in specs}
    assert got == want
    # one spec per (path, layer): nothing written twice
    assert len({(s.path, s.layer) for s in specs}) == len(specs)
    assert ("unembed/table" in got) == (not tied)


def test_every_spec_round_trips_bit_exactly(tmp_path):
    outdir, cfg, sd = make_fixture(tmp_path)
    params = load_hf_checkpoint(outdir, cfg)
    for spec in mapping_specs(cfg):
        want = TRANSFORMS[spec.transform](
            np.asarray(sd[spec.hf_name]), cfg.attention, cfg.d_model)
        got = _leaf(params, spec)
        assert got.shape == spec.shape, spec
        np.testing.assert_array_equal(got, want.astype(got.dtype), err_msg=spec.hf_name)


# -- GQA reshape oracles (independent of the loader's transforms) -----------


def test_q_proj_reshape_matches_hf_einsum_oracle(tmp_path):
    outdir, cfg, sd = make_fixture(tmp_path)
    params = load_hf_checkpoint(outdir, cfg)
    a = cfg.attention
    kv, g, d = a.num_kv_heads, a.num_heads // a.num_kv_heads, a.head_dim
    rng = np.random.default_rng(0)
    x = rng.standard_normal((5, cfg.d_model)).astype(np.float32)
    hf_w = np.asarray(sd["model.layers.0.self_attn.q_proj.weight"])
    hf_q = x @ hf_w.T                     # (5, H*D) — HF Linear semantics
    wq = np.asarray(_paths(params)["layers/attn/wq"][0])
    ours = np.einsum("sm,mkgd->skgd", x, wq)
    for k in range(kv):
        for gi in range(g):
            h = k * g + gi                # repeat_kv: query head h -> kv h//G
            np.testing.assert_allclose(
                ours[:, k, gi], hf_q[:, h * d:(h + 1) * d], atol=1e-5)


def test_o_proj_reshape_matches_hf_einsum_oracle(tmp_path):
    outdir, cfg, sd = make_fixture(tmp_path)
    params = load_hf_checkpoint(outdir, cfg)
    a = cfg.attention
    kv, g, d = a.num_kv_heads, a.num_heads // a.num_kv_heads, a.head_dim
    rng = np.random.default_rng(1)
    y = rng.standard_normal((5, kv, g, d)).astype(np.float32)
    hf_w = np.asarray(sd["model.layers.0.self_attn.o_proj.weight"])
    hf_out = y.reshape(5, kv * g * d) @ hf_w.T   # heads concat in h = kv*G+g order
    wo = np.asarray(_paths(params)["layers/attn/wo"][0])
    ours = np.einsum("skgd,kgdm->sm", y, wo)
    np.testing.assert_allclose(ours, hf_out, atol=1e-5)


# -- layout variants --------------------------------------------------------


def test_sharded_index_equals_single_file(tmp_path):
    out1, cfg, _ = make_fixture(tmp_path / "a", variant="single", seed=3)
    out2 = str(tmp_path / "b")
    write_hf_fixture(out2, variant="sharded", seed=3)
    assert len(resolve_tensor_files(out2)) > len(
        set(resolve_tensor_files(out2).values())) == 2
    p1, p2 = load_hf_checkpoint(out1, cfg), load_hf_checkpoint(out2, cfg)
    jax.tree.map(np.testing.assert_array_equal, p1, p2)


def test_direct_safetensors_file_path(tmp_path):
    outdir, cfg, _ = make_fixture(tmp_path)
    fname = os.path.join(outdir, "model.safetensors")
    p1 = load_hf_checkpoint(outdir, cfg)
    p2 = load_hf_checkpoint(fname, cfg)
    jax.tree.map(np.testing.assert_array_equal, p1, p2)


def test_tied_embeddings_variant(tmp_path):
    outdir, cfg, sd = make_fixture(tmp_path, tied=True)
    assert cfg.tie_embeddings and "lm_head.weight" not in sd
    params = load_hf_checkpoint(outdir, cfg)
    assert "unembed" not in params
    logits = build_model(cfg).forward(
        params, {"tokens": jnp.zeros((1, 4), jnp.int32)})
    assert logits.shape == (1, 4, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


def test_qkv_bias_variant(tmp_path):
    outdir, cfg, sd = make_fixture(tmp_path, bias=True)
    assert cfg.attention.qkv_bias
    params = load_hf_checkpoint(outdir, cfg)
    a = cfg.attention
    kv, g, d = a.num_kv_heads, a.num_heads // a.num_kv_heads, a.head_dim
    leaves = _paths(params)
    assert leaves["layers/attn/bq"].shape == (cfg.num_layers, kv, g, d)
    assert leaves["layers/attn/bk"].shape == (cfg.num_layers, kv, d)
    hf_b = np.asarray(sd["model.layers.0.self_attn.q_proj.bias"])
    np.testing.assert_array_equal(
        np.asarray(leaves["layers/attn/bq"][0]).reshape(-1), hf_b)


def test_extra_tensors_are_ignored(tmp_path):
    outdir, cfg, _ = make_fixture(tmp_path, extra_tensors=True)
    params = load_hf_checkpoint(outdir, cfg)   # rotary_emb.inv_freq present
    assert "layers" in params


def test_bf16_stored_weights_cast_to_param_dtype(tmp_path):
    outdir, cfg, sd = make_fixture(tmp_path, dtype="bfloat16", seed=5)
    params = load_hf_checkpoint(outdir, cfg)
    spec = next(
        s for s in mapping_specs(cfg) if s.path == ("embed", "table"))
    got = _leaf(params, spec)
    assert got.dtype == np.float32        # cfg.param_dtype
    # bit-exact vs the f32 source rounded through the stored bf16
    want = (np.asarray(sd[spec.hf_name])
            .astype(ml_dtypes.bfloat16).astype(np.float32))
    np.testing.assert_array_equal(got, want)


# -- error paths ------------------------------------------------------------


def test_missing_tensor_names_both_sides(tmp_path):
    from safetensors.numpy import load_file, save_file

    outdir, cfg, _ = make_fixture(tmp_path)
    fname = os.path.join(outdir, "model.safetensors")
    sd = load_file(fname)
    del sd["model.layers.1.mlp.down_proj.weight"]
    save_file(sd, fname)
    with pytest.raises(KeyError) as ei:
        load_hf_checkpoint(outdir, cfg)
    msg = str(ei.value)
    assert "model.layers.1.mlp.down_proj.weight" in msg
    assert "layers/ffn/w2" in msg


def test_wrong_shape_raises(tmp_path):
    outdir, cfg, _ = make_fixture(tmp_path)
    bad = dataclasses.replace(
        cfg, d_ff=cfg.d_ff * 2)            # specs now expect (M, 2F)
    with pytest.raises(ValueError, match="shape"):
        load_hf_checkpoint(outdir, bad)


def test_missing_checkpoint_dir(tmp_path):
    with pytest.raises(FileNotFoundError):
        resolve_tensor_files(str(tmp_path / "nope"))


# -- config.json -> ModelConfig ---------------------------------------------


def test_config_from_hf_fields(tmp_path):
    outdir, cfg, _ = make_fixture(tmp_path)
    hf = QWEN3_TINY
    assert cfg.d_model == hf["hidden_size"]
    assert cfg.num_layers == hf["num_hidden_layers"]
    assert cfg.d_ff == hf["intermediate_size"]
    assert cfg.vocab_size == hf["vocab_size"]
    a = cfg.attention
    assert a.num_heads == hf["num_attention_heads"]
    assert a.num_kv_heads == hf["num_key_value_heads"]
    assert a.head_dim == hf["head_dim"]
    assert a.qk_norm and not a.qkv_bias    # qwen3
    assert a.rope_theta == hf["rope_theta"]
    assert not cfg.tie_embeddings
    with open(os.path.join(outdir, "config.json")) as f:
        raw = json.load(f)
    assert raw["model_type"] == "qwen3"


def test_config_from_hf_rejects_unknown_model_type(tmp_path):
    outdir = str(tmp_path / "hf_ckpt")
    write_hf_fixture(outdir, config_overrides={"model_type": "mamba"})
    with pytest.raises(ValueError, match="mamba"):
        config_from_hf(outdir)


# -- loaded weights serve identically to an in-process tree -----------------


def test_logits_identical_to_in_process_params(tmp_path):
    """Assemble the param tree in-process from the same raw arrays (spec
    transforms applied leaf by leaf, layers stacked by hand) and require
    bit-identical logits — the loader's shard grouping / stacking / cast
    pipeline must be a pure re-arrangement."""
    outdir, cfg, sd = make_fixture(tmp_path, seed=11)
    loaded = load_hf_checkpoint(outdir, cfg)
    model = build_model(cfg)
    template = model.init(jax.random.PRNGKey(0))

    by_path = {}
    for spec in mapping_specs(cfg):
        arr = TRANSFORMS[spec.transform](
            np.asarray(sd[spec.hf_name]), cfg.attention,
            cfg.d_model).astype(np.float32)
        key = "/".join(spec.path)
        if spec.layer is None:
            by_path[key] = arr
        else:
            by_path.setdefault(key, {})[spec.layer] = arr
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, _ in flat:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        v = by_path[key]
        leaves.append(jnp.asarray(
            v if isinstance(v, np.ndarray)
            else np.stack([v[i] for i in range(cfg.num_layers)])))
    manual = treedef.unflatten(leaves)

    tokens = {"tokens": jnp.arange(12, dtype=jnp.int32)[None, :] % 7}
    la = np.asarray(model.forward(loaded, tokens))
    lb = np.asarray(model.forward(manual, tokens))
    np.testing.assert_array_equal(la, lb)
    assert np.isfinite(la).all()


# -- CheckpointManager integration ------------------------------------------


def test_manager_import_hf_round_trip(tmp_path):
    outdir, cfg, _ = make_fixture(tmp_path)
    mgr = CheckpointManager(str(tmp_path / "native"), keep=2)
    params = mgr.import_hf(outdir, cfg, step=0)
    assert mgr.all_steps() == [0]
    restored, step = mgr.restore(None, params)
    assert step == 0
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), params, restored)


def test_manager_projections_sidecar(tmp_path):
    from repro.core.calibration import AquaProjections

    mgr = CheckpointManager(str(tmp_path / "native"))
    assert mgr.load_aqua_projections() is None
    rng = np.random.default_rng(2)
    proj = AquaProjections(
        p=jnp.asarray(rng.standard_normal((2, 2, 16, 16)), jnp.float32))
    mgr.save_aqua_projections(proj)
    assert os.path.exists(mgr.projections_path)
    back = mgr.load_aqua_projections()
    np.testing.assert_array_equal(np.asarray(back.p), np.asarray(proj.p))
