"""Engine-level paged-serving tests: token identity vs the contiguous
cache (single-device and mesh2x2, greedy), prefix-shared admissions with
zero prefill recompute, pool-exhaustion queueing, and the pool-based
``cache_bytes`` accounting (single source of truth vs jax.eval_shape)."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import reduced
from repro.configs.base import (AquaConfig, CacheSpec, QuantSpec,
                                ServingConfig)
from repro.core.calibration import identity_projections
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, Request
from repro.serving.engine import decode_state_bytes


@pytest.fixture(scope="module")
def dense_model():
    cfg = dataclasses.replace(reduced("qwen3-0.6b"), remat=False,
                              dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _trace(cfg, n=5, max_new=6, seed=3, prefix=None):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size,
                            size=(int(rng.integers(4, 22)),), dtype=np.int32)
        if prefix is not None:
            toks = np.concatenate([prefix, toks])
        reqs.append(Request(uid=i, tokens=toks, max_new_tokens=max_new,
                            arrival=float(i)))
    return reqs


SCFG = ServingConfig(max_lanes=4, max_seq=64, max_new_tokens=6,
                     prompt_bucket=8)
PSCFG = dataclasses.replace(SCFG, cache=CacheSpec(page_size=8,
                                                  num_pages=24))


def _proj(cfg):
    return identity_projections(cfg.num_layers, cfg.attention.num_kv_heads,
                                cfg.attention.head_dim)


POLICIES = {
    "dense-jnp": dict(aqua=None, backend="dense-jnp"),
    "aqua-masked-dense": dict(aqua=AquaConfig(k_ratio=0.75, block_dims=1),
                              backend="aqua-masked-dense"),
    "aqua-block-sparse": dict(aqua=AquaConfig(k_ratio=0.5, block_dims=8),
                              backend="aqua-block-sparse"),
    "window": dict(aqua=None, backend="dense-jnp", window=16),
}


def _engine(dense_model, policy, scfg, mesh=None):
    cfg, params = dense_model
    spec = POLICIES[policy]
    if spec.get("window"):
        att = dataclasses.replace(cfg.attention, window=spec["window"],
                                  kind="swa")
        cfg = dataclasses.replace(cfg, attention=att)
    cfg = dataclasses.replace(cfg, aqua=spec["aqua"])
    proj = _proj(cfg) if spec["aqua"] is not None else None
    return ContinuousBatchingEngine(cfg, params, proj, serving=scfg,
                                    backend=spec["backend"], mesh=mesh)


@pytest.mark.parametrize("policy", list(POLICIES))
def test_paged_token_identity(dense_model, policy):
    """Greedy decode from the paged pool must be token-identical to the
    contiguous lane-stripe cache for every policy the paged layout keeps
    slot-identical (full, window, AQUA backends incl. the paged Pallas
    decode kernel)."""
    cfg, _ = dense_model
    reqs = _trace(cfg)
    cont = _engine(dense_model, policy, SCFG)
    paged = _engine(dense_model, policy, PSCFG)
    outs_c = cont.run([dataclasses.replace(r) for r in reqs])
    outs_p = paged.run([dataclasses.replace(r) for r in reqs])
    for uid in outs_c:
        assert outs_c[uid].tokens == outs_p[uid].tokens, (policy, uid)


def test_paged_token_identity_mesh2x2(dense_model):
    if jax.device_count() < 4:
        pytest.skip("needs 4 forced host devices")
    from repro.launch.mesh import make_serving_mesh
    cfg, _ = dense_model
    mesh = make_serving_mesh((2, 2))
    reqs = _trace(cfg)
    cont = _engine(dense_model, "dense-jnp", SCFG, mesh=mesh)
    paged = _engine(dense_model, "dense-jnp", PSCFG, mesh=mesh)
    outs_c = cont.run([dataclasses.replace(r) for r in reqs])
    outs_p = paged.run([dataclasses.replace(r) for r in reqs])
    for uid in outs_c:
        assert outs_c[uid].tokens == outs_p[uid].tokens


def test_h2o_paged_serves_and_evicts_pages(dense_model):
    """Page-granular H2O: the drive finishes, and generations past the
    budget force whole-page evictions (pool positions stay consistent)."""
    cfg, params = dense_model
    cfg = dataclasses.replace(cfg, aqua=AquaConfig(k_ratio=0.75,
                                                   h2o_ratio=0.5,
                                                   block_dims=1))
    eng = ContinuousBatchingEngine(cfg, params, _proj(cfg), serving=PSCFG,
                                   backend="aqua-masked-dense")
    assert eng.pool_geometry[1] == 4        # 32-slot budget / 8-token pages
    reqs = _trace(cfg, n=3, max_new=20)     # 20 new + prompt > 32 budget
    outs = eng.run(reqs)
    assert all(len(o.tokens) == 20 for o in outs.values())


def test_prefix_sharing_zero_recompute(dense_model):
    """A trace whose prompts share a page-aligned prefix admits all but
    the first request with the prefix pages mapped read-only — the saved
    prefill tokens are exactly (hits x prefix_len) and outputs match the
    unshared paged engine at greedy."""
    cfg, _ = dense_model
    prefix = np.random.default_rng(11).integers(
        0, cfg.vocab_size, size=(16,), dtype=np.int32)
    reqs = _trace(cfg, n=4, prefix=prefix, seed=5)
    shared = _engine(dense_model, "dense-jnp", PSCFG)
    outs_s = shared.run([dataclasses.replace(r) for r in reqs])
    pool = shared.page_pool
    assert pool.prefix_hits >= 2
    assert pool.tokens_saved == pool.prefix_hits * 16
    noshare = _engine(
        dense_model, "dense-jnp",
        dataclasses.replace(PSCFG, cache=CacheSpec(page_size=8,
                                                   num_pages=24,
                                                   prefix_sharing=False)))
    outs_n = noshare.run([dataclasses.replace(r) for r in reqs])
    assert noshare.page_pool.prefix_hits == 0
    for uid in outs_s:
        assert outs_s[uid].tokens == outs_n[uid].tokens


def test_prefix_extension_registers_longer_chain(dense_model):
    """A prompt that extends a shared prefix by further full pages must
    register those pages too: a third identical prompt then shares the
    whole extended prefix, not just the first registrant's pages."""
    cfg, _ = dense_model
    rng = np.random.default_rng(21)
    P = rng.integers(0, cfg.vocab_size, size=(16,), dtype=np.int32)
    Q = rng.integers(0, cfg.vocab_size, size=(9,), dtype=np.int32)
    reqs = [
        Request(uid=0, tokens=P, max_new_tokens=10, arrival=0.0),
        Request(uid=1, tokens=np.concatenate([P, Q]), max_new_tokens=10,
                arrival=1.0),
        Request(uid=2, tokens=np.concatenate([P, Q]), max_new_tokens=10,
                arrival=2.0),
    ]
    eng = _engine(dense_model, "dense-jnp", PSCFG)
    outs = eng.run(reqs)
    assert all(len(o.tokens) == 10 for o in outs.values())
    pool = eng.page_pool
    # uid 1 shares P's 2 pages (16 tokens); uid 2 shares the extended
    # 3-page chain uid 1 registered (24 tokens)
    assert pool.prefix_hits == 2
    assert pool.tokens_saved == 16 + 24


def test_prefix_admission_ignores_stale_recycled_pages(dense_model):
    """Regression: a prefix-shared admission maps *recycled* pool pages
    for its tail, and those pages still hold the previous tenant's
    positions when the tail prefill gathers the prefix view (clearing
    happens in paged_write_tail, after the read). Stale positions inside
    the prefix range must not pass the prefix mask — the slot-index guard
    in DenseLM.prefill_with_prefix keeps the admission token-identical to
    the contiguous engine.

    Construction: C keeps the shared prefix pages alive; A (unshared,
    prompt == one full prefix-worth of pages, positions 0..15) retires
    immediately so its dirty pages sit on the free list; B's tail is long
    enough that the LIFO allocator hands it A's position-0..7 page.
    """
    cfg, _ = dense_model
    rng = np.random.default_rng(42)
    pre = rng.integers(0, cfg.vocab_size, size=(16,), dtype=np.int32)
    C = Request(uid=0, tokens=np.concatenate(
        [pre, rng.integers(0, cfg.vocab_size, size=(4,), dtype=np.int32)]),
        max_new_tokens=30, arrival=0.0)
    A = Request(uid=1, tokens=rng.integers(0, cfg.vocab_size, size=(16,),
                                           dtype=np.int32),
                max_new_tokens=1, arrival=0.0)
    B = Request(uid=2, tokens=np.concatenate(
        [pre, rng.integers(0, cfg.vocab_size, size=(14,), dtype=np.int32)]),
        max_new_tokens=8, arrival=3.0)
    scfg = dataclasses.replace(SCFG, max_lanes=2, max_new_tokens=8,
                               cache=CacheSpec(page_size=8, num_pages=12))
    eng = _engine((cfg, dense_model[1]), "dense-jnp", scfg)
    outs = eng.run([C, A, B])
    assert eng.page_pool.prefix_hits == 1   # B really shared the prefix
    ref = _engine((cfg, dense_model[1]), "dense-jnp",
                  dataclasses.replace(scfg, cache=CacheSpec()))
    outs_r = ref.run([dataclasses.replace(r) for r in (C, A, B)])
    for uid in outs:
        assert outs[uid].tokens == outs_r[uid].tokens, uid


def test_pool_exhaustion_queues_requests(dense_model):
    """A pool too small for concurrent admissions serializes them instead
    of failing: every request still completes, and the allocator ends the
    drive with all pages free."""
    cfg, _ = dense_model
    tight = dataclasses.replace(SCFG, cache=CacheSpec(page_size=8,
                                                      num_pages=6))
    eng = _engine(dense_model, "dense-jnp", tight)
    reqs = _trace(cfg, n=4, seed=9)
    outs = eng.run(reqs)
    assert all(len(o.tokens) == 6 for o in outs.values())
    assert eng.page_pool.pages_in_use == 0
    assert eng.page_pool.peak_in_use <= 6


def test_int8_paged_engine_serves_and_shrinks_cache(dense_model):
    """QuantSpec(kv_dtype='int8') end to end: the drive completes, the
    resolved specs surface on the engine, and the quantized pool
    undercuts the full-precision paged pool by at least the CI gate."""
    cfg, _ = dense_model
    qscfg = dataclasses.replace(PSCFG, quant=QuantSpec(kv_dtype="int8"))
    eng = _engine(dense_model, "aqua-block-sparse", qscfg)
    assert eng.quant_spec.quantized and eng.cache_spec.paged
    outs = eng.run(_trace(cfg, n=3, seed=4))
    assert all(len(o.tokens) == 6 for o in outs.values())
    fp = _engine(dense_model, "aqua-block-sparse", PSCFG)
    assert eng.cache_bytes() <= 0.60 * fp.cache_bytes()


def test_int8_mixed_precision_serves_on_reference_path(dense_model):
    """hot_resident_fraction > 0 allocates the bf16 overlay and keeps the
    engine off the kernel path (REASON_QUANT_RESIDENCY) — the drive still
    completes through the dequantized lane view."""
    cfg, _ = dense_model
    qscfg = dataclasses.replace(
        PSCFG, quant=QuantSpec(kv_dtype="int8",
                               hot_resident_fraction=0.25))
    eng = _engine(dense_model, "dense-jnp", qscfg)
    assert eng.dispatch_plan().quantization == "int8-mixed"
    outs = eng.run(_trace(cfg, n=3, seed=4))
    assert all(len(o.tokens) == 6 for o in outs.values())


def test_pool_too_small_raises(dense_model):
    cfg, _ = dense_model
    tiny = dataclasses.replace(SCFG, cache=CacheSpec(page_size=8,
                                                     num_pages=1))
    eng = _engine(dense_model, "dense-jnp", tiny)
    with pytest.raises(RuntimeError, match="page pool"):
        eng.run(_trace(cfg, n=1))


# ---------------------------------------------------------------------------
# cache_bytes: single source of truth, matches jax.eval_shape totals
# ---------------------------------------------------------------------------


def _eval_shape_bytes(model, lanes, max_seq):
    state = jax.eval_shape(lambda: model.init_decode_state(lanes, max_seq))
    return sum(np.prod(a.shape) * a.dtype.itemsize
               for a in jax.tree.leaves(state.layers))


@pytest.mark.parametrize("policy_aqua", [
    ("full", None),
    ("aqua-mem", AquaConfig(k_ratio=0.75, s_ratio=0.25, block_dims=1)),
    ("h2o", AquaConfig(k_ratio=0.75, h2o_ratio=0.5, block_dims=1)),
    ("window", None),
])
@pytest.mark.parametrize("page_size", [None, 8])
def test_cache_bytes_matches_eval_shape(dense_model, policy_aqua,
                                        page_size):
    cfg, params = dense_model
    name, aqua = policy_aqua
    if name == "window":
        att = dataclasses.replace(cfg.attention, window=16, kind="swa")
        cfg = dataclasses.replace(cfg, attention=att)
    cfg = dataclasses.replace(cfg, aqua=aqua)
    # 6 pages sits below lane-stripe parity for every policy here (full:
    # 32 pages, H2O budget: 16, window: 8) so the undercut check is valid;
    # no drive runs in this test, only shape accounting
    scfg = dataclasses.replace(
        SCFG, cache=CacheSpec(page_size=page_size,
                              num_pages=6 if page_size else None))
    eng = ContinuousBatchingEngine(
        cfg, params, _proj(cfg) if aqua else None, serving=scfg,
        backend="aqua-masked-dense" if aqua else "dense-jnp")
    expect = _eval_shape_bytes(eng.model, scfg.max_lanes, scfg.max_seq)
    assert eng.cache_bytes() == expect
    assert decode_state_bytes(eng.model, scfg.max_lanes,
                              scfg.max_seq) == expect
    if page_size is not None:
        # the pool (20 pages) must undercut lane-stripe parity bytes
        stripe = decode_state_bytes(build_model(cfg), scfg.max_lanes,
                                    scfg.max_seq)
        assert eng.cache_bytes() < stripe


def test_rectangular_engine_cache_bytes_shares_accounting(dense_model):
    from repro.serving import ServeEngine
    cfg, params = dense_model
    eng = ServeEngine(cfg, params, max_seq=64)
    assert eng.cache_bytes(4) == _eval_shape_bytes(eng.model, 4, 64)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
