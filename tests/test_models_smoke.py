"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step (or serve step), asserting shapes and finiteness.
The FULL configs are exercised only by the dry-run (no allocation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_config, reduced
from repro.configs.base import AquaConfig, TrainConfig
from repro.data.pipeline import DataConfig, add_frontend_inputs, make_batch
from repro.models import build_model


def _batch(cfg, b=2, s=16, seed=0):
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=s, global_batch=b,
                      seed=seed)
    return add_frontend_inputs(make_batch(dcfg, 0), cfg)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    out = model.forward(params, batch)
    if isinstance(out, tuple):
        out = out[0]
    assert out.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(out, np.float32)).all()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch):
    from repro.launch.train import TrainState, make_train_step
    from repro.optim import adamw
    cfg = dataclasses.replace(reduced(arch), remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params=params, opt=adamw.init(params),
                       step=jnp.zeros((), jnp.int32))
    step_fn = jax.jit(make_train_step(model, TrainConfig(warmup_steps=1,
                                                         total_steps=10)))
    batch = _batch(cfg)
    state, metrics = step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0.0
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_serve_step(arch):
    cfg = reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, b=2, s=8)
    logits, state = model.prefill(params, {k: v for k, v in batch.items()
                                           if k != "labels"}, max_seq=32)
    assert logits.shape == (2, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, state = model.decode_step(params, state, tok)
    assert logits2.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "olmoe-1b-7b",
                                  "recurrentgemma-9b", "whisper-tiny"])
def test_serve_step_with_aqua(arch):
    from repro.core.calibration import identity_projections
    cfg = dataclasses.replace(
        reduced(arch), aqua=AquaConfig(k_ratio=0.75, s_ratio=0.25))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    nl = cfg.num_layers if cfg.family != "hybrid" else model.num_attn_layers
    proj = identity_projections(nl, cfg.attention.num_kv_heads,
                                cfg.attention.head_dim).p
    batch = _batch(cfg, b=2, s=8)
    logits, state = model.prefill(params, {k: v for k, v in batch.items()
                                           if k != "labels"},
                                  max_seq=32, aqua_proj=proj)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, _ = model.decode_step(params, state, tok, aqua_proj=proj)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    # AQUA-Memory: cached key dim is statically sliced
    kept = cfg.aqua.kept_dims(cfg.attention.head_dim)
    caches = (state.layers if isinstance(state.layers, tuple)
              else [state.layers])
    from repro.core.kvcache import AttnCache
    k_dims = [c.k.shape[-1] for c in caches if isinstance(c, AttnCache)]
    assert all(kd == kept for kd in k_dims), (k_dims, kept)


def test_full_configs_match_assignment():
    """The production configs carry the exact assigned hyperparameters."""
    expect = {
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }
    for arch, (l, dm, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert cfg.num_layers == l and cfg.d_model == dm
        assert cfg.attention.num_heads == h
        assert cfg.attention.num_kv_heads == kv
        assert cfg.d_ff == ff and cfg.vocab_size == v
    m = get_config("mamba2-370m")
    assert (m.num_layers, m.d_model, m.vocab_size) == (48, 1024, 50280)
    assert m.ssm.state_dim == 128 and m.attention is None
    moe = get_config("olmoe-1b-7b").moe
    assert moe.num_experts == 64 and moe.top_k == 8
    q2 = get_config("qwen2-moe-a2.7b").moe
    assert q2.num_experts == 60 and q2.top_k == 4 and q2.num_shared == 4
