"""Run one forward + one serve step for EVERY assigned architecture at
reduced scale — the '--arch' selector tour.

    PYTHONPATH=src python examples/multiarch_smoke.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, reduced
from repro.data.pipeline import DataConfig, add_frontend_inputs, make_batch
from repro.models import build_model


def main():
    for arch in ASSIGNED_ARCHS:
        t0 = time.time()
        cfg = reduced(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=2)
        batch = add_frontend_inputs(
            {"tokens": make_batch(dcfg, 0)["tokens"]}, cfg)
        logits, state = model.prefill(params, batch, max_seq=32)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        logits2, _ = model.decode_step(params, state, tok)
        ok = bool(np.isfinite(np.asarray(logits2, np.float32)).all())
        n_params = sum(a.size for a in jax.tree.leaves(params))
        print(f"{arch:20s} family={cfg.family:7s} params={n_params:>9,d} "
              f"prefill+decode {'OK' if ok else 'FAIL'} "
              f"({time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
