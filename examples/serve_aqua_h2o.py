"""AQUA-H2O serving (paper §8.3): approximate attention scores drive the
heavy-hitter eviction statistic; the cache is capped at h2o_ratio of the
context while decoding stays coherent.

    PYTHONPATH=src python examples/serve_aqua_h2o.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import reduced
from repro.configs.base import AquaConfig
from repro.core.calibration import identity_projections
from repro.data.pipeline import DataConfig, make_batch
from repro.models import build_model
from repro.serving import ServeEngine


def main():
    cfg = dataclasses.replace(reduced("olmoe-1b-7b"), remat=False,
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    proj = identity_projections(cfg.num_layers, cfg.attention.num_kv_heads,
                                cfg.attention.head_dim)

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=48, global_batch=2)
    prompt = {"tokens": make_batch(dcfg, 0)["tokens"]}

    print(f"{'policy':32s} {'cache slots':>12s} {'cache bytes':>12s}")
    for name, aqua in [
        ("full attention", None),
        ("AQUA k=0.75", AquaConfig(k_ratio=0.75)),
        ("AQUA-H2O k=0.75 budget=50%",
         AquaConfig(k_ratio=0.75, h2o_ratio=0.5)),
        ("AQUA-Memory s=0.25 k=0.75",
         AquaConfig(k_ratio=0.75, s_ratio=0.25)),
    ]:
        c = dataclasses.replace(cfg, aqua=aqua)
        eng = ServeEngine(c, params, proj if aqua else None, max_seq=128)
        res = eng.generate(prompt, steps=8)
        state = eng.model.init_decode_state(2, 128)
        from repro.core.kvcache import AttnCache
        slots = jax.tree.leaves(
            state.layers.k if not isinstance(state.layers, tuple)
            else state.layers[0].k)[0].shape[-2]
        print(f"{name:32s} {slots:12d} {eng.cache_bytes(2):12,d}")
        assert np.isfinite(res.logits_last).all()


if __name__ == "__main__":
    main()
