"""AQUA-H2O continuous-batching serving (paper §8.3 + §8 deployment
story): calibrate once, pick a (k_ratio, s_ratio, h2o_ratio) operating
point, then serve mixed-length traffic through the lane scheduler —
approximate attention scores drive the heavy-hitter eviction statistic
while requests stream in and out of a fixed set of decode lanes.

    PYTHONPATH=src python examples/serve_aqua_h2o.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import reduced
from repro.configs.base import AquaConfig, ServingConfig
from repro.core.calibration import identity_projections
from repro.serving import ContinuousBatchingEngine, Request
from repro.models import build_model


def main():
    cfg = dataclasses.replace(reduced("olmoe-1b-7b"), remat=False,
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    proj = identity_projections(cfg.num_layers, cfg.attention.num_kv_heads,
                                cfg.attention.head_dim)

    # mixed-length prompts, staggered arrivals (decode-step time units)
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, tokens=rng.integers(0, cfg.vocab_size, size=(s,),
                                           dtype=np.int32),
                max_new_tokens=8, arrival=float(a))
        for i, (s, a) in enumerate([(12, 0.0), (48, 0.0), (24, 2.0),
                                    (8, 5.0), (36, 6.0)])
    ]

    print(f"{'policy':32s} {'cache bytes':>12s} {'tokens':>7s} "
          f"{'occupancy':>10s}")
    for name, aqua in [
        ("full attention", None),
        ("AQUA k=0.75", AquaConfig(k_ratio=0.75)),
        ("AQUA-H2O k=0.75 budget=50%",
         AquaConfig(k_ratio=0.75, h2o_ratio=0.5)),
        ("AQUA-Memory s=0.25 k=0.75",
         AquaConfig(k_ratio=0.75, s_ratio=0.25)),
    ]:
        c = dataclasses.replace(cfg, aqua=aqua)
        eng = ContinuousBatchingEngine(
            c, params, proj if aqua else None,
            serving=ServingConfig(max_lanes=3, max_seq=128,
                                  max_new_tokens=8))
        outs = eng.run(reqs)
        assert all(o.finish_reason for o in outs.values())
        print(f"{name:32s} {eng.cache_bytes():12,d} "
              f"{eng.stats.tokens_emitted:7d} "
              f"{eng.stats.mean_occupancy:10.2f}")

    # streaming view of one policy
    eng = ContinuousBatchingEngine(
        dataclasses.replace(cfg, aqua=AquaConfig(k_ratio=0.75,
                                                 h2o_ratio=0.5)),
        params, proj,
        serving=ServingConfig(max_lanes=3, max_seq=128, max_new_tokens=8))
    print("\nstreaming (uid:token):", end=" ")
    for ev in eng.serve(reqs):
        print(f"{ev.uid}:{ev.token}" + ("!" if ev.finished else ""),
              end=" ")
    print()


if __name__ == "__main__":
    main()
