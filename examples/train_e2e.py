"""End-to-end training driver: train a ~reduced model for a few hundred
steps on the copy language with checkpointing and auto-resume, then verify
the trained model serves correctly through the AQUA engine.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""
import argparse
import dataclasses
import tempfile

import jax
import numpy as np

from repro.configs import reduced
from repro.configs.base import AquaConfig, TrainConfig
from repro.core.calibration import calibrate
from repro.data.pipeline import DataConfig, calibration_batches, make_batch
from repro.launch.train import Trainer
from repro.models import build_model
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen3-0.6b")
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced(args.arch, vocab=64, d_model=96),
                              remat=False, dtype="float32")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=20,
                       total_steps=args.steps,
                       checkpoint_every=max(50, args.steps // 4))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                      global_batch=16, kind="copy")

    with tempfile.TemporaryDirectory() as ckdir:
        trainer = Trainer(cfg, tcfg, dcfg, ckpt_dir=ckdir)
        state, losses = trainer.run(args.steps, log_every=50)
        print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")

        # calibrate AQUA on the trained weights and serve
        model = build_model(cfg)

        def fwd_cap(p, b):
            _, aux = model.forward(p, b, capture=True)
            return aux
        proj = calibrate(fwd_cap, state.params,
                         calibration_batches(cfg, num_batches=2, batch=4,
                                             seq=64), cfg)
        aqua_cfg = dataclasses.replace(
            cfg, aqua=AquaConfig(k_ratio=0.75, h2o_ratio=0.5))
        eng = ServeEngine(aqua_cfg, state.params, proj, max_seq=128)
        prompt = make_batch(dcfg, 12345)["tokens"][:2, :32]
        res = eng.generate({"tokens": prompt}, steps=16)
        print("generated:", np.asarray(res.tokens[0]).tolist())
        # on the copy task the continuation should echo the prompt
        echo = (np.asarray(res.tokens[0])[:16]
                == np.asarray(prompt[0])[-16 + 1:][:16])
        print(f"copy-task echo accuracy: {echo.mean():.2f}")


if __name__ == "__main__":
    main()
