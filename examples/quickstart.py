"""Quickstart: AQUA in ~60 lines.

Builds a small GQA transformer, computes the offline projection matrices
(paper §6.1), and compares exact attention with AQUA at the paper's sweet
spot (k_ratio = 0.75, §8.2).

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import reduced
from repro.configs.base import AquaConfig
from repro.core.calibration import calibrate
from repro.data.pipeline import DataConfig, calibration_batches, make_batch
from repro.models import build_model
from repro.models.layers import cross_entropy


def main():
    # 1. a reduced qwen3-family config (same GQA structure as production)
    cfg = dataclasses.replace(reduced("qwen3-0.6b"), remat=False,
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # 2. offline phase: collect post-RoPE q/k activations on a calibration
    #    corpus and SVD per (layer, GQA group) -> projection matrices P.
    def forward_with_capture(p, batch):
        _, aux = model.forward(p, batch, capture=True)
        return aux

    projections = calibrate(
        forward_with_capture, params,
        calibration_batches(cfg, num_batches=2, batch=2, seq=64), cfg)
    print("projection matrices:", projections.p.shape,
          "(layers, kv_heads, d_head, d_head)")

    # 3. online phase: evaluate with and without AQUA.
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)
    batch = make_batch(dcfg, 0)

    exact = model.forward(params, batch)
    nll_exact = float(cross_entropy(exact, batch["labels"]))

    aqua_cfg = dataclasses.replace(cfg, aqua=AquaConfig(k_ratio=0.75))
    aqua_model = build_model(aqua_cfg)
    approx = aqua_model.forward(params, batch, aqua_proj=projections.p)
    nll_aqua = float(cross_entropy(approx, batch["labels"]))

    print(f"exact attention NLL: {nll_exact:.4f}")
    print(f"AQUA k=0.75    NLL: {nll_aqua:.4f}  "
          f"(25% of score dims pruned per query)")
    # k_ratio=1.0 is exactly lossless (orthogonal rotation, Lemma A.4)
    full = dataclasses.replace(cfg, aqua=AquaConfig(k_ratio=1.0))
    nll_full = float(cross_entropy(
        build_model(full).forward(params, batch, aqua_proj=projections.p),
        batch["labels"]))
    print(f"AQUA k=1.0     NLL: {nll_full:.4f}  (== exact, rotation only)")


if __name__ == "__main__":
    main()
