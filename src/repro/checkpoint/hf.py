"""HF-format safetensors ingestion: real checkpoint -> repro param tree.

Maps Hugging Face transformers state dicts (llama / qwen2 / qwen3
families) onto the repro parameter tree with *explicit per-tensor
mapping specs* (:func:`mapping_specs`): every repro leaf names the HF
tensor it comes from, the transform that reshapes it, and the exact
shape it must produce, so the mapping is testable tensor-by-tensor
against a numpy oracle rather than "the load didn't crash".

Layout differences handled here:

* HF ``nn.Linear`` stores ``(out_features, in_features)``; the repro
  einsums contract ``(in, out)`` — every projection transposes.
* GQA head packing: HF ``q_proj`` rows are ``[head0 | head1 | ...]``
  with query head ``h`` reading KV head ``h // group_size`` (the
  ``repeat_kv`` convention). The repro layout ``(d_model, KV, G, D)``
  is exactly that grouping, so a reshape after the transpose is the
  whole transform — verified against an einsum oracle in
  ``tests/test_hf_loader.py``.
* ``o_proj`` ``(d_model, H*D)`` transposes then reshapes to the repro
  ``(KV, G, D, d_model)``.
* RMSNorm placement: ``input_layernorm`` -> ``ln1`` (pre-attention),
  ``post_attention_layernorm`` -> ``ln2`` (pre-MLP); qwen3's per-head
  ``q_norm``/``k_norm`` land inside the attention params.
* Gated MLP: ``gate_proj`` -> ``w1``, ``up_proj`` -> ``w3``,
  ``down_proj`` -> ``w2`` (see ``models.layers.mlp``).
* Tied embeddings (``tie_word_embeddings``) omit ``lm_head.weight``;
  the repro tree then has no ``unembed`` entry.
* Sharded checkpoints resolve through ``model.safetensors.index.json``
  (tensors are fetched lazily per shard file — an 8B checkpoint never
  materializes twice).

Per-layer tensors stack into the repro convention of a leading
``num_layers`` axis on every ``layers/...`` leaf (the ``lax.scan``
layout produced by ``jax.vmap`` at init time).

RoPE has no parameters on either side (same rotate-half convention);
non-parameter extras like ``rotary_emb.inv_freq`` are ignored.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttentionConfig, ModelConfig

INDEX_NAME = "model.safetensors.index.json"
SINGLE_NAME = "model.safetensors"


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """One repro-tree leaf: where it comes from and how it gets there."""

    hf_name: str
    # path inside the repro param tree, e.g. ("layers", "attn", "wq");
    # per-layer specs carry their layer index separately and stack.
    path: Tuple[str, ...]
    transform: str
    # repro-side shape this spec must produce (per layer, without the
    # stacked leading L axis)
    shape: Tuple[int, ...]
    layer: Optional[int] = None


def _t_identity(arr: np.ndarray, acfg: AttentionConfig, d_model: int):
    return arr


def _t_linear(arr: np.ndarray, acfg: AttentionConfig, d_model: int):
    """HF Linear (out, in) -> repro (in, out)."""
    return arr.T


def _t_q_proj(arr: np.ndarray, acfg: AttentionConfig, d_model: int):
    """(H*D, d_model) -> (d_model, KV, G, D)."""
    kv, g, d = acfg.num_kv_heads, acfg.group_size, acfg.head_dim
    return arr.T.reshape(d_model, kv, g, d)


def _t_kv_proj(arr: np.ndarray, acfg: AttentionConfig, d_model: int):
    """(KV*D, d_model) -> (d_model, KV, D)."""
    kv, d = acfg.num_kv_heads, acfg.head_dim
    return arr.T.reshape(d_model, kv, d)


def _t_o_proj(arr: np.ndarray, acfg: AttentionConfig, d_model: int):
    """(d_model, H*D) -> (KV, G, D, d_model)."""
    kv, g, d = acfg.num_kv_heads, acfg.group_size, acfg.head_dim
    return arr.T.reshape(kv, g, d, d_model)


def _t_q_bias(arr: np.ndarray, acfg: AttentionConfig, d_model: int):
    """(H*D,) -> (KV, G, D)."""
    kv, g, d = acfg.num_kv_heads, acfg.group_size, acfg.head_dim
    return arr.reshape(kv, g, d)


def _t_kv_bias(arr: np.ndarray, acfg: AttentionConfig, d_model: int):
    """(KV*D,) -> (KV, D)."""
    return arr.reshape(acfg.num_kv_heads, acfg.head_dim)


TRANSFORMS: Dict[str, Callable[..., np.ndarray]] = {
    "identity": _t_identity,
    "linear_t": _t_linear,
    "q_proj": _t_q_proj,
    "kv_proj": _t_kv_proj,
    "o_proj": _t_o_proj,
    "q_bias": _t_q_bias,
    "kv_bias": _t_kv_bias,
}


def mapping_specs(cfg: ModelConfig) -> List[TensorSpec]:
    """The full, explicit tensor mapping for ``cfg`` (dense llama/qwen
    geometry). Every leaf of the repro param tree appears exactly once."""
    acfg = cfg.attention
    assert acfg is not None, "HF ingestion covers attention models"
    m, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    kv, g, d = acfg.num_kv_heads, acfg.group_size, acfg.head_dim
    specs = [
        TensorSpec(
            "model.embed_tokens.weight", ("embed", "table"), "identity", (v, m)
        ),
        TensorSpec("model.norm.weight", ("ln_f",), "identity", (m,)),
    ]
    if not cfg.tie_embeddings:
        specs.append(
            TensorSpec("lm_head.weight", ("unembed", "table"), "identity", (v, m))
        )
    for i in range(cfg.num_layers):
        pre = f"model.layers.{i}."
        attn = pre + "self_attn."
        layer = [
            TensorSpec(
                pre + "input_layernorm.weight", ("layers", "ln1"), "identity", (m,)
            ),
            TensorSpec(
                pre + "post_attention_layernorm.weight",
                ("layers", "ln2"),
                "identity",
                (m,),
            ),
            TensorSpec(
                attn + "q_proj.weight",
                ("layers", "attn", "wq"),
                "q_proj",
                (m, kv, g, d),
            ),
            TensorSpec(
                attn + "k_proj.weight", ("layers", "attn", "wk"), "kv_proj", (m, kv, d)
            ),
            TensorSpec(
                attn + "v_proj.weight", ("layers", "attn", "wv"), "kv_proj", (m, kv, d)
            ),
            TensorSpec(
                attn + "o_proj.weight",
                ("layers", "attn", "wo"),
                "o_proj",
                (kv, g, d, m),
            ),
            TensorSpec(
                pre + "mlp.gate_proj.weight",
                ("layers", "ffn", "w1"),
                "linear_t",
                (m, f),
            ),
            TensorSpec(
                pre + "mlp.up_proj.weight", ("layers", "ffn", "w3"), "linear_t", (m, f)
            ),
            TensorSpec(
                pre + "mlp.down_proj.weight",
                ("layers", "ffn", "w2"),
                "linear_t",
                (f, m),
            ),
        ]
        if acfg.qk_norm:
            layer += [
                TensorSpec(
                    attn + "q_norm.weight",
                    ("layers", "attn", "q_norm"),
                    "identity",
                    (d,),
                ),
                TensorSpec(
                    attn + "k_norm.weight",
                    ("layers", "attn", "k_norm"),
                    "identity",
                    (d,),
                ),
            ]
        if acfg.qkv_bias:
            layer += [
                TensorSpec(
                    attn + "q_proj.bias", ("layers", "attn", "bq"), "q_bias", (kv, g, d)
                ),
                TensorSpec(
                    attn + "k_proj.bias", ("layers", "attn", "bk"), "kv_bias", (kv, d)
                ),
                TensorSpec(
                    attn + "v_proj.bias", ("layers", "attn", "bv"), "kv_bias", (kv, d)
                ),
            ]
        specs.extend(dataclasses.replace(s, layer=i) for s in layer)
    return specs


# ---------------------------------------------------------------------------
# File resolution + tensor fetch
# ---------------------------------------------------------------------------


def resolve_tensor_files(path: str) -> Dict[str, str]:
    """{tensor name: absolute safetensors file} for a checkpoint at
    ``path`` — a directory in HF layout (single ``model.safetensors`` or a
    sharded ``model.safetensors.index.json``) or a direct ``.safetensors``
    file."""
    from safetensors import safe_open

    def names_in(fname: str) -> Dict[str, str]:
        with safe_open(fname, framework="numpy") as f:
            return {name: fname for name in f.keys()}

    if os.path.isfile(path):
        return names_in(path)
    index = os.path.join(path, INDEX_NAME)
    if os.path.exists(index):
        with open(index) as f:
            weight_map = json.load(f)["weight_map"]
        return {
            name: os.path.join(path, shard) for name, shard in weight_map.items()
        }
    single = os.path.join(path, SINGLE_NAME)
    if os.path.exists(single):
        return names_in(single)
    cands = (
        sorted(n for n in os.listdir(path) if n.endswith(".safetensors"))
        if os.path.isdir(path)
        else []
    )
    if len(cands) == 1:
        return names_in(os.path.join(path, cands[0]))
    raise FileNotFoundError(
        f"no HF safetensors checkpoint at {path!r} (expected {SINGLE_NAME}, "
        f"{INDEX_NAME}, or a single .safetensors file)"
    )


def load_hf_checkpoint(path: str, cfg: ModelConfig, *, dtype=None) -> dict:
    """Load an HF safetensors checkpoint into the repro param tree.

    ``dtype`` defaults to ``cfg.param_dtype``; stored bf16 tensors are
    cast on load (the bf16->f32 widening is exact). Missing tensors raise
    ``KeyError`` naming the tensor and the repro leaf it was meant to
    fill; a tensor whose transform produces the wrong shape raises
    ``ValueError`` (geometry mismatch between ``cfg`` and the files).
    Returns the same nested-dict tree ``model.init`` would produce, with
    every ``layers/...`` leaf stacked over the leading layer axis.
    """
    from safetensors import safe_open

    acfg = cfg.attention
    out_dtype = np.dtype(dtype if dtype is not None else cfg.param_dtype)
    locations = resolve_tensor_files(path)
    specs = mapping_specs(cfg)

    # fetch shard-by-shard so multi-file checkpoints stream one file at a
    # time instead of opening per tensor
    by_file: Dict[str, List[TensorSpec]] = {}
    for spec in specs:
        fname = locations.get(spec.hf_name)
        if fname is None:
            leaf = "/".join(spec.path) + (
                f"[{spec.layer}]" if spec.layer is not None else ""
            )
            raise KeyError(
                f"HF checkpoint at {path!r} is missing tensor "
                f"{spec.hf_name!r} (needed for repro leaf {leaf!r}; "
                f"{len(locations)} tensors present)"
            )
        by_file.setdefault(fname, []).append(spec)

    raw: Dict[str, np.ndarray] = {}
    for fname, file_specs in sorted(by_file.items()):
        with safe_open(fname, framework="numpy") as f:
            for spec in file_specs:
                raw[spec.hf_name] = f.get_tensor(spec.hf_name)

    # group per-layer specs by tree path, apply transforms, stack L
    singles: Dict[Tuple[str, ...], np.ndarray] = {}
    stacked: Dict[Tuple[str, ...], Dict[int, np.ndarray]] = {}
    for spec in specs:
        arr = TRANSFORMS[spec.transform](raw[spec.hf_name], acfg, cfg.d_model)
        if tuple(arr.shape) != tuple(spec.shape):
            raise ValueError(
                f"tensor {spec.hf_name!r} maps to shape {tuple(arr.shape)}, "
                f"expected {tuple(spec.shape)} for repro leaf "
                f"{'/'.join(spec.path)!r} — checkpoint geometry does not "
                f"match config {cfg.name!r}"
            )
        arr = np.ascontiguousarray(arr).astype(out_dtype)
        if spec.layer is None:
            singles[spec.path] = arr
        else:
            stacked.setdefault(spec.path, {})[spec.layer] = arr

    tree: Dict[str, Any] = {}

    def place(tpath: Tuple[str, ...], value: np.ndarray) -> None:
        node = tree
        for key in tpath[:-1]:
            node = node.setdefault(key, {})
        node[tpath[-1]] = jnp.asarray(value)

    for tpath, arr in singles.items():
        place(tpath, arr)
    for tpath, per_layer in stacked.items():
        place(tpath, np.stack([per_layer[i] for i in range(cfg.num_layers)]))
    return tree


# ---------------------------------------------------------------------------
# HF config.json -> ModelConfig
# ---------------------------------------------------------------------------

# model_type values this ingestion path understands (all dense
# llama-geometry decoders)
SUPPORTED_MODEL_TYPES = ("llama", "qwen2", "qwen3")


def config_from_hf(path: str, *, name: Optional[str] = None) -> ModelConfig:
    """Build a repro ``ModelConfig`` from an HF ``config.json``.

    Serving-oriented defaults: float32 params/activations and
    ``remat=False`` (the repro engine recomputes nothing at inference;
    override with ``dataclasses.replace`` for training-style use).
    """
    cfg_path = path if os.path.isfile(path) else os.path.join(path, "config.json")
    with open(cfg_path) as f:
        hf = json.load(f)
    model_type = hf.get("model_type", "llama")
    if model_type not in SUPPORTED_MODEL_TYPES:
        raise ValueError(
            f"unsupported model_type {model_type!r} in {cfg_path!r} "
            f"(supported: {SUPPORTED_MODEL_TYPES})"
        )
    heads = int(hf["num_attention_heads"])
    hidden = int(hf["hidden_size"])
    attention = AttentionConfig(
        num_heads=heads,
        num_kv_heads=int(hf.get("num_key_value_heads", heads)),
        head_dim=int(hf.get("head_dim", hidden // heads)),
        qk_norm=model_type == "qwen3",
        qkv_bias=bool(hf.get("attention_bias", model_type == "qwen2")),
        rope_theta=float(hf.get("rope_theta", 10000.0)),
    )
    return ModelConfig(
        name=name or hf.get("_name_or_path", model_type),
        family="dense",
        num_layers=int(hf["num_hidden_layers"]),
        d_model=hidden,
        d_ff=int(hf["intermediate_size"]),
        vocab_size=int(hf["vocab_size"]),
        attention=attention,
        norm_eps=float(hf.get("rms_norm_eps", 1e-6)),
        tie_embeddings=bool(hf.get("tie_word_embeddings", False)),
        dtype="float32",
        param_dtype="float32",
        remat=False,
    )
