"""Fault-tolerant checkpointing.

Properties required at 1000-node scale and implemented here:
  * **atomic** — write to a temp dir, fsync, rename; a crash mid-save never
    corrupts the latest checkpoint.
  * **keep-N** — bounded disk usage with monotonic step naming.
  * **async** — a background thread serializes a host copy while the next
    step runs (device->host copy happens synchronously, serialization
    doesn't block training).
  * **elastic / mesh-agnostic** — arrays are stored *logically unsharded*
    (fully gathered); restore places them onto whatever mesh/sharding the
    new job uses, so a 256-chip checkpoint restores onto 512 chips (or 8)
    unchanged. ``restore(..., shardings=...)`` does reshard-on-load.
  * **self-describing** — a JSON manifest records the step, pytree
    structure and array metadata for validation.

Real-weights ingestion rides the same storage: ``import_hf`` maps an
HF-format safetensors checkpoint (``checkpoint.hf``) into the repro tree
and saves it as a native step, and AQUA projection artifacts
(``core.calibration``) live *beside* the checkpoints as an
``aqua_projections.npz`` sidecar in the same directory, so one manifest
location carries both the weights and their calibration.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Optional

import jax
import ml_dtypes
import numpy as np

# dtypes numpy's savez can't serialize -> (storage view dtype, restore dtype)
_VIEW_CODEC = {
    "bfloat16": (np.uint16, ml_dtypes.bfloat16),
    "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
    "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2),
}

PROJECTIONS_NAME = "aqua_projections.npz"


def _encode(arr: np.ndarray) -> np.ndarray:
    codec = _VIEW_CODEC.get(str(arr.dtype))
    return arr.view(codec[0]) if codec else arr


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    codec = _VIEW_CODEC.get(dtype_name)
    return arr.view(codec[1]) if codec else arr


def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- paths ---------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}")

    def all_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("ckpt_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except (IndexError, ValueError):
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ----------------------------------------------------------
    def save(self, step: int, tree, *, blocking: bool = True) -> None:
        """Serialize ``tree`` for ``step``. ``blocking=False`` runs
        serialization on a background thread (the device->host copy is
        still synchronous, so the caller may mutate device arrays)."""
        host = _flatten(tree)
        if blocking:
            self._write(step, host)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True
            )
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict) -> None:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(
            os.path.join(tmp, "arrays.npz"),
            **{k: _encode(v) for k, v in host.items()},
        )
        manifest = {
            "step": step,
            "time": time.time(),
            "arrays": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in host.items()
            },
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------
    def restore(self, step: Optional[int], target, *, shardings=None):
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs). ``shardings``: matching pytree of NamedSharding
        for reshard-on-load; None -> default device placement."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = self._step_dir(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["step"] == step
        data = np.load(os.path.join(path, "arrays.npz"))
        flat, treedef = jax.tree_util.tree_flatten_with_path(target)
        shard_flat = None if shardings is None else treedef.flatten_up_to(shardings)
        leaves = []
        for i, (p, leaf) in enumerate(flat):
            key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
            arr = _decode(data[key], manifest["arrays"][key]["dtype"])
            expect = tuple(leaf.shape)
            if tuple(arr.shape) != expect:
                raise ValueError(
                    f"shape mismatch for {key}: {arr.shape} vs {expect}"
                )
            if shard_flat is not None and shard_flat[i] is not None:
                leaves.append(jax.device_put(arr, shard_flat[i]))
            else:
                leaves.append(jax.device_put(arr.astype(leaf.dtype)))
        return treedef.unflatten(leaves), step

    # -- HF ingestion + AQUA projection sidecar ---------------------------
    def import_hf(self, hf_path: str, cfg, *, step: int = 0):
        """Ingest an HF safetensors checkpoint (``checkpoint.hf``) and save
        it as native step ``step``. Returns the loaded param tree, so the
        caller can serve from it immediately without a restore pass."""
        from repro.checkpoint.hf import load_hf_checkpoint

        params = load_hf_checkpoint(hf_path, cfg)
        self.save(step, params)
        return params

    @property
    def projections_path(self) -> str:
        """The AQUA projection sidecar beside the checkpoint steps."""
        return os.path.join(self.directory, PROJECTIONS_NAME)

    def save_aqua_projections(self, proj) -> None:
        """Save an ``AquaProjections`` artifact beside the checkpoints
        (atomic: tmp + rename, like the step dirs)."""
        from repro.core.calibration import save_projections

        tmp = self.projections_path + ".tmp"
        save_projections(tmp, proj)
        os.replace(tmp, self.projections_path)

    def load_aqua_projections(self):
        """Load the projection sidecar, or None when absent."""
        from repro.core.calibration import load_projections

        if not os.path.exists(self.projections_path):
            return None
        return load_projections(self.projections_path)
