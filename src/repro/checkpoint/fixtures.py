"""Synthetic HF-checkpoint fixture generator (CI has no network).

Writes a tiny random qwen3-geometry checkpoint in *genuine* HF layout —
``config.json`` plus safetensors file(s) with transformers tensor names
and HF-side shapes (``q_proj.weight`` as ``(H*D, hidden)`` etc.) — so
the whole real-weights path (``checkpoint/hf.py`` ingestion -> corpus
calibration -> paged/mesh serving -> quality bench) exercises offline.
The tensor name list and shapes below are written against the HF
llama/qwen3 state-dict format directly, independent of
``hf.mapping_specs``, so a mapping bug cannot hide behind a fixture
generated from the same table.

Variants:

* ``variant="single"`` — one ``model.safetensors``.
* ``variant="sharded"`` — two shard files plus a
  ``model.safetensors.index.json`` weight map (the multi-file layout
  real >2GB checkpoints ship in).
* ``tied=True`` — ``tie_word_embeddings`` with no ``lm_head.weight``.
* ``dtype="bfloat16"`` — stores bf16 tensors (the common HF distribution
  dtype); ingestion casts on load.

CLI (CI acceptance drive)::

    PYTHONPATH=src python -m repro.checkpoint.fixtures /tmp/hf_fixture \\
        --variant sharded --seed 0
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional

import ml_dtypes
import numpy as np

# Tiny qwen3 geometry: GQA (kv < heads), qk-norm, tied-embedding-capable.
# head_dim=16 admits block_dims=8 dim-block kernels; vocab 256 makes the
# byte-level calibration corpus an exact fit.
QWEN3_TINY: Dict[str, object] = {
    "model_type": "qwen3",
    "hidden_size": 64,
    "num_hidden_layers": 2,
    "num_attention_heads": 4,
    "num_key_value_heads": 2,
    "head_dim": 16,
    "intermediate_size": 128,
    "vocab_size": 256,
    "rms_norm_eps": 1e-6,
    "rope_theta": 1000000.0,
    "tie_word_embeddings": False,
    "torch_dtype": "float32",
}


def fixture_state_dict(
    config: Dict[str, object], *, seed: int = 0
) -> Dict[str, np.ndarray]:
    """Random float32 tensors under HF transformers names/shapes."""
    rng = np.random.default_rng(seed)
    hidden = int(config["hidden_size"])
    layers = int(config["num_hidden_layers"])
    heads = int(config["num_attention_heads"])
    kv = int(config.get("num_key_value_heads", heads))
    d = int(config.get("head_dim", hidden // heads))
    ff = int(config["intermediate_size"])
    vocab = int(config["vocab_size"])
    qk_norm = config.get("model_type") == "qwen3"
    bias = bool(config.get("attention_bias", False))
    tied = bool(config.get("tie_word_embeddings", False))

    def w(*shape: int) -> np.ndarray:
        scale = 1.0 / np.sqrt(shape[-1]) if len(shape) > 1 else 0.02
        return (rng.standard_normal(shape) * scale).astype(np.float32)

    sd: Dict[str, np.ndarray] = {
        "model.embed_tokens.weight": w(vocab, hidden),
        "model.norm.weight": np.ones((hidden,), np.float32),
    }
    if not tied:
        sd["lm_head.weight"] = w(vocab, hidden)
    for i in range(layers):
        pre = f"model.layers.{i}."
        attn = pre + "self_attn."
        sd[pre + "input_layernorm.weight"] = np.ones((hidden,), np.float32)
        sd[pre + "post_attention_layernorm.weight"] = np.ones(
            (hidden,), np.float32
        )
        sd[attn + "q_proj.weight"] = w(heads * d, hidden)
        sd[attn + "k_proj.weight"] = w(kv * d, hidden)
        sd[attn + "v_proj.weight"] = w(kv * d, hidden)
        sd[attn + "o_proj.weight"] = w(hidden, heads * d)
        if qk_norm:
            sd[attn + "q_norm.weight"] = np.ones((d,), np.float32)
            sd[attn + "k_norm.weight"] = np.ones((d,), np.float32)
        if bias:
            sd[attn + "q_proj.bias"] = w(heads * d)
            sd[attn + "k_proj.bias"] = w(kv * d)
            sd[attn + "v_proj.bias"] = w(kv * d)
        sd[pre + "mlp.gate_proj.weight"] = w(ff, hidden)
        sd[pre + "mlp.up_proj.weight"] = w(ff, hidden)
        sd[pre + "mlp.down_proj.weight"] = w(hidden, ff)
    return sd


def write_hf_fixture(
    outdir: str,
    *,
    seed: int = 0,
    variant: str = "single",
    tied: bool = False,
    bias: bool = False,
    dtype: str = "float32",
    config_overrides: Optional[Dict[str, object]] = None,
    extra_tensors: bool = False,
) -> Dict[str, np.ndarray]:
    """Write a synthetic HF checkpoint to ``outdir``; returns the raw
    (float32, HF-layout) state dict the files were written from, so tests
    can oracle against the exact source arrays.

    ``extra_tensors`` adds a non-parameter ``rotary_emb.inv_freq`` entry
    (present in older HF exports) that ingestion must ignore.
    """
    from safetensors.numpy import save_file

    config = dict(QWEN3_TINY)
    config["tie_word_embeddings"] = tied
    if bias:
        config["attention_bias"] = True
    config["torch_dtype"] = dtype
    if config_overrides:
        config.update(config_overrides)
    sd = fixture_state_dict(config, seed=seed)

    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, "config.json"), "w") as f:
        json.dump(config, f, indent=2)

    stored = sd
    if dtype == "bfloat16":
        stored = {k: v.astype(ml_dtypes.bfloat16) for k, v in sd.items()}
    elif dtype != "float32":
        raise ValueError(f"unsupported fixture dtype {dtype!r}")

    if extra_tensors:
        stored = dict(stored)
        stored["model.layers.0.self_attn.rotary_emb.inv_freq"] = np.ones(
            (int(config["head_dim"]) // 2,), np.float32
        )

    if variant == "single":
        save_file(stored, os.path.join(outdir, "model.safetensors"))
    elif variant == "sharded":
        names = sorted(stored)
        half = len(names) // 2
        shards = {
            "model-00001-of-00002.safetensors": names[:half],
            "model-00002-of-00002.safetensors": names[half:],
        }
        weight_map = {}
        for fname, keys in shards.items():
            save_file(
                {k: stored[k] for k in keys}, os.path.join(outdir, fname)
            )
            weight_map.update({k: fname for k in keys})
        index = {
            "metadata": {
                "total_size": sum(v.nbytes for v in stored.values())
            },
            "weight_map": weight_map,
        }
        with open(os.path.join(outdir, "model.safetensors.index.json"), "w") as f:
            json.dump(index, f, indent=2)
    else:
        raise ValueError(f"unknown fixture variant {variant!r}")
    return sd


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("outdir")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--variant", default="single", choices=("single", "sharded"))
    ap.add_argument("--tied", action="store_true")
    ap.add_argument("--bias", action="store_true")
    ap.add_argument("--dtype", default="float32", choices=("float32", "bfloat16"))
    args = ap.parse_args(argv)
    sd = write_hf_fixture(
        args.outdir,
        seed=args.seed,
        variant=args.variant,
        tied=args.tied,
        bias=args.bias,
        dtype=args.dtype,
    )
    print(
        f"[fixtures] wrote {len(sd)} tensors ({args.variant}, "
        f"{args.dtype}) to {args.outdir}"
    )


if __name__ == "__main__":
    main()
