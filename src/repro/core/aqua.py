"""AQUA core primitives (paper §4, §6, §7).

Pure-functional JAX implementations of:
  * offline SVD projection computation (per GQA group),
  * dynamic magnitude-based dimension selection (per query, per step),
  * approximate score computation on the selected dims,
  * the paper's Information Retention Loss metric (§6.2),
  * AQUA-Memory static slicing (§8.4).

Masking identity used throughout (TPU adaptation, DESIGN.md §2): selecting
index set I from both q̂ and K̂ and dotting equals dotting (q̂ ⊙ m_I) with
the *full* K̂, since dropped coordinates contribute 0. The jnp reference
path uses the masked-dense form; the Pallas kernel realizes the actual
HBM-byte saving by not streaming unselected dim-blocks.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AquaConfig

# ---------------------------------------------------------------------------
# Offline: projection computation (paper §6.1, §6.3)
# ---------------------------------------------------------------------------


def compute_projection(d_calib: jax.Array) -> jax.Array:
    """SVD of the calibration matrix; returns P = V (d_head × d_head).

    ``d_calib``: (M, d_head) stacked query+key activations for one
    layer / GQA group (eq. D_calib^GQA in §6.3).
    """
    d_calib = d_calib.astype(jnp.float32)
    # Right singular vectors via eigh of the (d×d) Gram matrix — this is
    # Path 1 of appendix A.3 and is far cheaper than full SVD for M >> d.
    gram = d_calib.T @ d_calib
    eigval, eigvec = jnp.linalg.eigh(gram)
    # eigh returns ascending order; PCA wants descending variance.
    order = jnp.argsort(eigval)[::-1]
    return eigvec[:, order]


def gqa_calibration_matrix(queries: jax.Array, keys: jax.Array) -> jax.Array:
    """Stack per-group queries and the shared key head (paper §6.3).

    queries: (group_size, M, d_head); keys: (M, d_head)
    returns: ((group_size+1)*M, d_head)
    """
    g, m, d = queries.shape
    return jnp.concatenate([queries.reshape(g * m, d), keys], axis=0)


def check_orthogonal(p: jax.Array, atol: float = 1e-3) -> jax.Array:
    eye = jnp.eye(p.shape[-1], dtype=p.dtype)
    return jnp.max(jnp.abs(p @ p.T - eye)) < atol


# ---------------------------------------------------------------------------
# Online: magnitude-based dimension selection (paper §4 alg. 1, §7)
# ---------------------------------------------------------------------------


def ceil_to(n: int, m: int) -> int:
    """Smallest multiple of ``m`` >= ``n`` (shared padding/tiling helper)."""
    return -(-n // m) * m


def magnitude_mask(q_hat: jax.Array, k_dims: int, *, block_dims: int = 1
                   ) -> jax.Array:
    """0/1 mask over the last axis keeping the top-``k_dims`` dims by |q̂|.

    ``block_dims`` > 1 quantizes selection to contiguous blocks of that many
    dims (TPU sublane granularity; DESIGN.md §2). ``k_dims`` must then be a
    multiple of ``block_dims``.
    """
    d = q_hat.shape[-1]
    if k_dims >= d:
        return jnp.ones_like(q_hat, dtype=q_hat.dtype)
    mag = jnp.abs(q_hat.astype(jnp.float32))
    if block_dims == 1:
        # kth largest value as threshold; ties broken by index via top_k.
        _, idx = jax.lax.top_k(mag, k_dims)
        mask = jnp.zeros_like(mag)
        mask = jnp.put_along_axis(mask, idx, 1.0, axis=-1, inplace=False)
        return mask.astype(q_hat.dtype)
    assert d % block_dims == 0 and k_dims % block_dims == 0, (d, k_dims, block_dims)
    nb = d // block_dims
    kb = k_dims // block_dims
    bmag = mag.reshape(*mag.shape[:-1], nb, block_dims).sum(-1)
    _, bidx = jax.lax.top_k(bmag, kb)
    bmask = jnp.zeros_like(bmag)
    bmask = jnp.put_along_axis(bmask, bidx, 1.0, axis=-1, inplace=False)
    mask = jnp.repeat(bmask, block_dims, axis=-1)
    return mask.astype(q_hat.dtype)


def topk_block_indices(q_hat: jax.Array, k_dims: int, block_dims: int
                       ) -> jax.Array:
    """Selected dim-*block* indices (sorted ascending) for the Pallas
    scalar-prefetch path. Last axis of result has k_dims // block_dims."""
    d = q_hat.shape[-1]
    assert d % block_dims == 0 and k_dims % block_dims == 0
    nb, kb = d // block_dims, k_dims // block_dims
    mag = jnp.abs(q_hat.astype(jnp.float32))
    bmag = mag.reshape(*mag.shape[:-1], nb, block_dims).sum(-1)
    _, bidx = jax.lax.top_k(bmag, kb)
    return jnp.sort(bidx, axis=-1).astype(jnp.int32)


def chunk_topk_block_indices(q_hat: jax.Array, k_dims: int, block_dims: int,
                             q_chunk: int,
                             lengths: Optional[jax.Array] = None
                             ) -> jax.Array:
    """Per-query-*chunk* dim-block selection for the chunked-prefill kernel.

    The paper selects dims per query; a chunked kernel must share one block
    set across the ``q_chunk`` queries of a tile, so |q̂| block magnitudes
    are aggregated (summed) over each chunk before the top-k. At
    ``q_chunk=1`` this reduces exactly to :func:`topk_block_indices`.

    q_hat:   (B, H, S, D) projected queries (head-major kernel layout)
    lengths: (B,) — query rows at or beyond a row's length are excluded
             from the aggregation so padding never steers selection
    returns: (B, H, S // q_chunk, k_dims // block_dims) int32, sorted.
    """
    b, h, s, d = q_hat.shape
    assert s % q_chunk == 0, (s, q_chunk)
    assert d % block_dims == 0 and k_dims % block_dims == 0, \
        (d, k_dims, block_dims)
    nb, kb = d // block_dims, k_dims // block_dims
    mag = jnp.abs(q_hat.astype(jnp.float32))
    if lengths is not None:
        valid = jnp.arange(s)[None, :] < lengths[:, None]       # (B, S)
        mag = mag * valid[:, None, :, None]
    bmag = mag.reshape(b, h, s // q_chunk, q_chunk, nb, block_dims
                       ).sum(axis=(3, 5))                       # (B,H,NQC,NB)
    _, bidx = jax.lax.top_k(bmag, kb)
    return jnp.sort(bidx, axis=-1).astype(jnp.int32)


def approx_scores(q_hat: jax.Array, khat: jax.Array, mask: jax.Array
                  ) -> jax.Array:
    """S̃ = (q̂ ⊙ m) K̂ᵀ  — alg. 1 lines 6-8 in masked-dense form.

    q_hat: (..., d); khat: (..., S, d); mask: broadcastable to q_hat.
    returns (..., S).
    """
    return jnp.einsum("...d,...sd->...s", q_hat * mask, khat)


# ---------------------------------------------------------------------------
# AQUA-Memory static slicing (paper §8.4 stage 1)
# ---------------------------------------------------------------------------


def static_slice(v_hat: jax.Array, cfg: AquaConfig, head_dim: int) -> jax.Array:
    """Drop the trailing (lowest-variance) principal dims before caching."""
    kept = cfg.kept_dims(head_dim)
    return v_hat[..., :kept]


# ---------------------------------------------------------------------------
# Metrics (paper §6.2)
# ---------------------------------------------------------------------------


def info_retention_loss(v: jax.Array, v_hat: jax.Array, mask: jax.Array
                        ) -> jax.Array:
    """L_info = | ||v|| − ||v̂[I_k]|| | / ||v||  (elementwise over batch)."""
    v = v.astype(jnp.float32)
    v_hat = v_hat.astype(jnp.float32)
    norm_v = jnp.linalg.norm(v, axis=-1)
    norm_kept = jnp.linalg.norm(v_hat * mask, axis=-1)
    return jnp.abs(norm_v - norm_kept) / jnp.maximum(norm_v, 1e-12)


def slicing_mask(d: int, k_dims: int, like: jax.Array) -> jax.Array:
    """LoKi-style naive static slice mask (first k dims) — the baseline the
    paper compares against in Fig. 2."""
    m = (jnp.arange(d) < k_dims).astype(like.dtype)
    return jnp.broadcast_to(m, like.shape[:-1] + (d,))


# ---------------------------------------------------------------------------
# Weight folding (DESIGN.md §2): store W_Q P and W_K P offline.
# ---------------------------------------------------------------------------


def fold_projection_into_weights(wq: jax.Array, wk: jax.Array, p: jax.Array
                                 ) -> Tuple[jax.Array, jax.Array]:
    """Legal only when nothing (e.g. RoPE) sits between projection and use.
    wq/wk: (..., d_model, H, d_head) or (d_model, d_head); p: (d_head, d_head).
    """
    return wq @ p, wk @ p


def project(x: jax.Array, p: Optional[jax.Array]) -> jax.Array:
    """q̂ = q P (runtime path, used when RoPE prevents folding)."""
    if p is None:
        return x
    return x @ p.astype(x.dtype)
