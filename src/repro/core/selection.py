"""Two-stage hierarchical selection pipeline (ROADMAP item 2).

This module unifies the selection machinery that used to live in three
places — dim-block top-k in ``core/aqua.py``, backend dispatch and chunk
tile masks in ``core/attention.py``, and per-kernel index plumbing — into
one pipeline behind :class:`repro.configs.base.SparsitySpec`, producing a
per-step :class:`SelectionPlan`:

  * **Stage 1 — token sparsity (page-granular):** rank a lane's mapped
    pages by the H2O accumulated attention mass the paged pool already
    maintains (``PagedAttnCache.acc_pool``) and keep only the top
    ``kept_pages`` as *participants*; the trailing ``pin_recent_pages``
    pages (probe token, local window) are always kept. This is the
    HyperAttention composition — a coarse token-level stage in front of a
    finer approximation — but reusing our own statistics instead of LSH.
  * **Stage 2 — dim sparsity:** AQUA's per-query |q̂| dim-block top-k
    (``core.aqua.topk_block_indices``), unchanged, applied only within
    participating pages.

The plan's tables ride the Pallas kernels' ``PrefetchScalarGridSpec``
scalar-prefetch ``index_map`` machinery exactly like page ids and quant
scales (``kernels/aqua_decode.py``), so non-participating pages cost
zero HBM bytes: decode bandwidth scales with ``kept_pages × kept
dim-blocks``, not context length. ``page_keep_ratio=1.0`` resolves to
the identity participation table — the kernel walks the same tiles in
the same order and is bit-identical to the plain paged path.

Ranking semantics (shared by the jit path, the numpy ``--verify``
oracle, and the property tests):

  * page mass = per-lane sum of the page's ``acc_pool`` scores, gathered
    through the lane's own page table — shared/CoW physical pages score
    *per lane*, not per pool;
  * the trailing ``pin_recent_pages`` mapped pages rank ``+inf``
    (recency pin — never dropped);
  * logical pages beyond the lane's token count rank ``-inf`` (they hold
    no attendable tokens; keeping them last makes the table
    deterministic — kernel validity masking drops them anyway);
  * ties resolve to the lowest page index (``lax.top_k`` semantics), so
    a zero-statistic cache degrades to attention-sink (earliest pages)
    plus the pinned recent tail;
  * the participating set is sorted ascending, so a full keep ratio is
    the identity map.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aqua as aqua_lib


@jax.tree_util.register_dataclass
@dataclass
class SelectionPlan:
    """One decode step's resolved two-stage selection.

    block_idx: (B, H, NB_sel) int32 — stage-2 dim-block indices (sorted
       ascending; ``core.aqua.topk_block_indices``).
    pages: (B, KP) int32 — stage-1 participating *logical* page indices
       per lane (sorted ascending), or None when every page participates
       (no token sparsity). Entries are always valid logical indices in
       ``[0, pages_per_lane)``; empty/unmapped pages that pad the set are
       masked by the kernels' position validity test.
    """

    block_idx: jax.Array
    pages: Optional[jax.Array] = None


def page_scores(acc_pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """Per-lane page mass: (P, KV, ps) pool × (B, NP) table -> (B, NP).

    Gathered through the lane's table so shared (CoW/prefix) physical
    pages contribute to every lane that maps them — ranking is per-lane.
    Unmapped entries (-1) score 0 instead of borrowing page 0's mass.
    """
    score = acc_pool[jnp.maximum(page_table, 0)].sum(axis=(2, 3))
    return jnp.where(page_table >= 0, score, 0.0)


def participating_pages(acc_pool: jax.Array, page_table: jax.Array,
                        count: jax.Array, *, page_size: int,
                        kept_pages: int,
                        pin_recent_pages: int) -> jax.Array:
    """Stage-1 selection: (B, kept_pages) int32 logical page indices,
    sorted ascending (see the module docstring for ranking semantics).
    ``count`` (B,) is the lane's token count at read time — the page
    holding position ``count - 1`` anchors the recency pin.
    """
    b, npl = page_table.shape
    score = page_scores(acc_pool, page_table)                  # (B, NP)
    pidx = jnp.arange(npl, dtype=jnp.int32)[None, :]
    tail = jnp.maximum((count[:, None] - 1) // page_size, 0)   # (B, 1)
    pinned = (pidx > tail - pin_recent_pages) & (pidx <= tail)
    score = jnp.where(pinned, jnp.inf, score)
    score = jnp.where(pidx > tail, -jnp.inf, score)
    _, top = jax.lax.top_k(score, kept_pages)
    return jnp.sort(top, axis=-1).astype(jnp.int32)


def reference_participating_pages(acc_pool, page_table, count, *,
                                  page_size: int, kept_pages: int,
                                  pin_recent_pages: int) -> np.ndarray:
    """Numpy twin of :func:`participating_pages` — the ``--verify``
    page-ranking oracle and the property-test reference. Identical
    ranking, pin, tie (stable lowest-index-first) and sort semantics,
    computed host-side in float32 like the jit path."""
    acc = np.asarray(acc_pool)
    table = np.asarray(page_table)
    cnt = np.asarray(count)
    b, npl = table.shape
    out = np.zeros((b, kept_pages), np.int32)
    pidx = np.arange(npl)
    for i in range(b):
        score = acc[np.maximum(table[i], 0)].sum(
            axis=(1, 2), dtype=np.float32)
        score[table[i] < 0] = 0.0
        tail = max((int(cnt[i]) - 1) // page_size, 0)
        score[(pidx > tail - pin_recent_pages) & (pidx <= tail)] = np.inf
        score[pidx > tail] = -np.inf
        top = np.argsort(-score, kind="stable")[:kept_pages]
        out[i] = np.sort(top)
    return out


def build_decode_plan(q_hat: jax.Array, cache, *, topk_dims: int,
                      block_dims: int,
                      kept_pages: Optional[int] = None,
                      pin_recent_pages: int = 2) -> SelectionPlan:
    """Resolve one decode step's :class:`SelectionPlan`.

    q_hat: (B, H, Dk) projected (unmasked) queries, head-flattened as the
    decode kernels consume them. ``cache`` is a
    :class:`repro.core.kvcache.PagedAttnCache`. ``kept_pages`` None (or
    the full page count) disables stage 1 — ``plan.pages`` is None and
    the kernels take their existing non-hierarchical path.
    """
    block_idx = aqua_lib.topk_block_indices(q_hat, topk_dims, block_dims)
    pages = None
    if kept_pages is not None and kept_pages < cache.pages_per_lane:
        pages = participating_pages(
            cache.acc_pool, cache.page_table, cache.count,
            page_size=cache.page_size, kept_pages=kept_pages,
            pin_recent_pages=pin_recent_pages)
    return SelectionPlan(block_idx=block_idx, pages=pages)


def participation_slot_mask(pages: jax.Array, *, page_size: int,
                            num_slots: int) -> jax.Array:
    """(B, KP) participating pages -> (B, S_log) bool slot mask — the
    masked-dense reference's view of stage 1 (slot attendable iff its
    logical page participates). The reference path composes this with
    the usual position validity mask so it attends exactly the token set
    the hierarchical kernel streams."""
    npl = num_slots // page_size
    hit = (jnp.arange(npl, dtype=jnp.int32)[None, :, None]
           == pages[:, None, :]).any(-1)                       # (B, NP)
    return jnp.repeat(hit, page_size, axis=1)


def chunk_participating_tiles(scores: jax.Array, *, nqc: int, q_blk: int,
                              k_blk: int, kept_tiles: int,
                              pin_tiles: int = 1,
                              q_offset: int = 0) -> jax.Array:
    """Q-tile-granular stage-1 analogue for the chunked prefill kernel.

    ``scores`` (B, NKC): per-k-tile mass (e.g. page mass from earlier
    chunks aggregated to kernel tiles; zeros degrade to sink + diagonal).
    For each q-tile the ``pin_tiles`` k-tiles at the causal diagonal are
    pinned (the tile attending itself is always exact) and tiles strictly
    beyond the diagonal rank ``-inf`` (the kernel's causal skip ignores
    them regardless). Returns (B, NQC, kept_tiles) int32, sorted
    ascending per q-tile.
    """
    b, nkc = scores.shape
    diag = (q_offset + (jnp.arange(nqc) + 1) * q_blk - 1) // k_blk
    tidx = jnp.arange(nkc, dtype=jnp.int32)[None, None, :]
    d = diag[None, :, None]
    s = jnp.broadcast_to(scores[:, None, :].astype(jnp.float32),
                         (b, nqc, nkc))
    s = jnp.where((tidx > d - pin_tiles) & (tidx <= d), jnp.inf, s)
    s = jnp.where(tidx > d, -jnp.inf, s)
    _, top = jax.lax.top_k(s, kept_tiles)
    return jnp.sort(top, axis=-1).astype(jnp.int32)
