"""Offline AQUA calibration (paper §6.1): run the model over a calibration
corpus, collect post-transform (post-RoPE / post-qk-norm) query and key
activations per layer and GQA group, and compute the per-group SVD
projection matrices P.

Output artifact: ``AquaProjections`` — array (num_layers, num_kv_heads,
d_head, d_head), saved/loaded as .npz alongside checkpoints. Layers without
a QK dot product (SSM blocks, cross-attention) get identity entries.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@jax.tree_util.register_dataclass
@dataclass
class AquaProjections:
    """p: (num_layers, num_kv_heads, d_head, d_head)."""

    p: jax.Array

    def layer(self, i: int) -> jax.Array:
        return self.p[i]


def identity_projections(num_layers: int, num_kv: int, d: int
                         ) -> AquaProjections:
    eye = jnp.broadcast_to(jnp.eye(d), (num_layers, num_kv, d, d))
    return AquaProjections(p=eye)


def calibrate(forward_with_capture: Callable, params, batches: Iterable,
              cfg: ModelConfig, max_vectors: int = 16384) -> AquaProjections:
    """Compute projections from captured activations.

    ``forward_with_capture(params, tokens) -> aux`` must return
    ``aux["qk"]``: list over attention layers of (q, k) with
    q: (B, S, KV, G, D), k: (B, S, KV, D) — post-RoPE, exactly the vectors
    the online phase projects (paper §6.1 step 2).

    Accumulates Gram matrices streamingly (no giant concat) — equivalent to
    SVD right-singular-vectors of the stacked D_calib (appendix A.3 path 1).
    """
    acfg = cfg.attention
    assert acfg is not None, "calibration needs an attention model"
    d = acfg.head_dim
    kvh = acfg.num_kv_heads
    grams: Optional[np.ndarray] = None   # (L, KV, D, D)
    layer_ids: Optional[List[int]] = None
    seen = 0
    for tokens in batches:
        if seen >= max_vectors:
            break
        aux = forward_with_capture(params, tokens)
        qks = aux["qk"]
        if grams is None:
            grams = np.zeros((len(qks), kvh, d, d), np.float64)
            layer_ids = list(range(len(qks)))
        for li, (q, k) in enumerate(qks):
            b, s = q.shape[0], q.shape[1]
            # D_calib^GQA per group: queries of the group + the shared key.
            qm = np.asarray(q, np.float64).reshape(b * s, kvh, -1, d)
            km = np.asarray(k, np.float64).reshape(b * s, kvh, d)
            for h in range(kvh):
                dq = qm[:, h].reshape(-1, d)
                dmat = np.concatenate([dq, km[:, h]], axis=0)
                grams[li, h] += dmat.T @ dmat
        seen += int(np.prod(q.shape[:2]))
    assert grams is not None, "no calibration batches supplied"
    num_layers = grams.shape[0]
    p = np.zeros((num_layers, kvh, d, d), np.float32)
    for li in range(num_layers):
        for h in range(kvh):
            eigval, eigvec = np.linalg.eigh(grams[li, h])
            p[li, h] = eigvec[:, ::-1]  # descending variance
    return AquaProjections(p=jnp.asarray(p))


def save_projections(path: str, proj: AquaProjections) -> None:
    np.savez(path, p=np.asarray(proj.p))


def load_projections(path: str) -> AquaProjections:
    with np.load(path) as f:
        return AquaProjections(p=jnp.asarray(f["p"]))
