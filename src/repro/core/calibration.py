"""Offline AQUA calibration (paper §6.1): run the model over a calibration
corpus, collect post-transform (post-RoPE / post-qk-norm) query and key
activations per layer and GQA group, and compute the per-group SVD
projection matrices P.

Output artifact: ``AquaProjections`` — array (num_layers, num_kv_heads,
d_head, d_head), saved/loaded as .npz alongside checkpoints (see
``checkpoint.manager.CheckpointManager.save_aqua_projections`` for the
beside-the-checkpoint sidecar). Layers without a QK dot product (SSM
blocks, cross-attention) get identity entries: a capture path reports
them as ``None`` in ``aux["qk"]`` and :func:`calibrate` passes identity
through, so the projection array stays index-aligned with the stack.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@jax.tree_util.register_dataclass
@dataclass
class AquaProjections:
    """p: (num_layers, num_kv_heads, d_head, d_head)."""

    p: jax.Array

    def layer(self, i: int) -> jax.Array:
        return self.p[i]


def identity_projections(num_layers: int, num_kv: int, d: int) -> AquaProjections:
    eye = jnp.broadcast_to(jnp.eye(d), (num_layers, num_kv, d, d))
    return AquaProjections(p=eye)


def calibrate(
    forward_with_capture: Callable,
    params,
    batches: Iterable,
    cfg: ModelConfig,
    max_vectors: int = 16384,
) -> AquaProjections:
    """Compute projections from captured activations.

    ``forward_with_capture(params, tokens) -> aux`` must return
    ``aux["qk"]``: list over layers of (q, k) with q: (B, S, KV, G, D),
    k: (B, S, KV, D) — post-RoPE, exactly the vectors the online phase
    projects (paper §6.1 step 2). An entry may be ``None`` for a layer
    with no QK dot product (SSM block, cross-attention): that layer gets
    an identity projection, keeping the array index-aligned.

    Accumulates Gram matrices streamingly (no giant concat) — equivalent to
    SVD right-singular-vectors of the stacked D_calib (appendix A.3 path 1).
    The accumulation runs in float64 and the eigendecomposition is
    deterministic, so the same corpus and seed produce bit-identical
    projections.
    """
    acfg = cfg.attention
    assert acfg is not None, "calibration needs an attention model"
    d = acfg.head_dim
    kvh = acfg.num_kv_heads
    grams: Optional[np.ndarray] = None  # (L, KV, D, D)
    touched: Optional[np.ndarray] = None  # (L,) any activations seen
    seen = 0
    for tokens in batches:
        if seen >= max_vectors:
            break
        aux = forward_with_capture(params, tokens)
        qks = aux["qk"]
        if grams is None:
            grams = np.zeros((len(qks), kvh, d, d), np.float64)
            touched = np.zeros(len(qks), bool)
        batch_vectors = 0
        for li, entry in enumerate(qks):
            if entry is None:
                continue  # no QK product in this layer -> identity below
            q, k = entry
            b, s = q.shape[0], q.shape[1]
            # D_calib^GQA per group: queries of the group + the shared key.
            qm = np.asarray(q, np.float64).reshape(b * s, kvh, -1, d)
            km = np.asarray(k, np.float64).reshape(b * s, kvh, d)
            for h in range(kvh):
                dq = qm[:, h].reshape(-1, d)
                dmat = np.concatenate([dq, km[:, h]], axis=0)
                grams[li, h] += dmat.T @ dmat
            touched[li] = True
            batch_vectors = b * s
        seen += batch_vectors
    assert grams is not None, "no calibration batches supplied"
    num_layers = grams.shape[0]
    p = np.zeros((num_layers, kvh, d, d), np.float32)
    for li in range(num_layers):
        if not touched[li]:
            p[li] = np.eye(d, dtype=np.float32)
            continue
        for h in range(kvh):
            eigval, eigvec = np.linalg.eigh(grams[li, h])
            p[li, h] = eigvec[:, ::-1]  # descending variance
    return AquaProjections(p=jnp.asarray(p))


def save_projections(path: str, proj: AquaProjections) -> None:
    # write through a file object so the exact path is honored
    # (np.savez appends ".npz" to bare string paths)
    with open(path, "wb") as f:
        np.savez(f, p=np.asarray(proj.p))


def load_projections(path: str) -> AquaProjections:
    with np.load(path) as f:
        return AquaProjections(p=jnp.asarray(f["p"]))
