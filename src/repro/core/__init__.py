"""The paper's contribution: AQUA attention approximation, calibration,
H2O coupling, and the unified cache machinery."""

from repro.core import aqua, attention, calibration, h2o, kvcache  # noqa: F401
