"""Unified multi-head attention with first-class AQUA support.

Covers: MHA / GQA / MQA, full + sliding-window/local masks, RoPE, qk-norm,
QKV bias, AQUA projection + magnitude selection, AQUA-Memory static slice,
and H2O heavy-hitter eviction — for both prefill (sequence) and decode
(single-step with slot cache) modes.

Backend registry contract
-------------------------
The core attention product is dispatched through a string-keyed registry
(:data:`_BACKENDS`); ``AttentionConfig.backend`` selects the entry and
:func:`resolve_backend` applies the fallback policy. A backend's
``prefill`` callable receives model-layout tensors

  q (B, S, KV, G, Dq), k (B, S, KV, Dq), v (B, S, KV, Dv)

and returns ``(out (B, S, KV, G, Dv), weights | None)``. Non-AQUA
backends and ``aqua-masked-dense`` get the magnitude-*masked* query
(masked-q identity, DESIGN.md §2); ``aqua-block-sparse`` gets the
unmasked projected q̂/k̂ and performs chunk-level dim-block selection
inside the kernel wrapper. ``decode`` (optional) receives the projected
query (B, KV, G, Dq) plus the slot cache and returns (B, KV, G, Dv).

Built-in backends: ``dense-jnp`` (materialized scores, auto-switching to
the chunked online-softmax scan for long sequences), ``flash`` (Pallas
flash kernel), ``aqua-masked-dense`` (jnp reference for AQUA),
``aqua-block-sparse`` (Pallas chunked-prefill + decode kernels streaming
only the selected dim-blocks). ``auto`` resolves to kernels on TPU and
jnp references elsewhere; kernel backends fall back to the masked-dense
reference when Pallas is unavailable (``runtime_flags.PALLAS_OVERRIDE``).

Conventions:
  x            (B, S, d_model)
  q            (B, S, KV, G, D)   G = group size (H = KV*G)
  k, v         (B, S, KV, D)
  proj P       (KV, D, D)         per-layer, per-GQA-group (paper §6.3)
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import functools
import logging
import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from repro import runtime_flags as _rtf

logger = logging.getLogger(__name__)


def _scan(*args, **kw):
    kw.update(_rtf.scan_kwargs())
    return jax.lax.scan(*args, **kw)


from repro.configs.base import AquaConfig, AttentionConfig
from repro.core import aqua as aqua_lib
from repro.core.aqua import ceil_to as _ceil_to
from repro.core import kvcache as kv
# single-source fallback-reason vocabulary: the dedup sink keys off these
# exact strings and DispatchPlan.reasons carries the same constants, so
# the plan's prediction and the trace-time warnings can never drift apart
from repro.core.dispatch import (REASON_NONDIVISIBLE_MESH,
                                 REASON_PAGE_GEOMETRY,
                                 REASON_QUANT_RESIDENCY)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding over the last axis. x: (..., S, ..., D) with
    positions broadcastable to x's sequence axis; here we require
    x: (B, S, *, D) and positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    # broadcast over head axes between S and D
    extra = x.ndim - 3  # number of axes between S and D
    for _ in range(extra):
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:2 * half]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    rest = x[..., 2 * half:]  # odd head dims (e.g. danube D=80 is even; safe)
    return jnp.concatenate([out1.astype(x.dtype), out2.astype(x.dtype), rest],
                           axis=-1)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Parameter init / QKV projection
# ---------------------------------------------------------------------------


def init_attention_params(rng: jax.Array, d_model: int, cfg: AttentionConfig,
                          dtype=jnp.float32) -> dict:
    h, g, d = cfg.num_kv_heads, cfg.group_size, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    std = d_model ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d_model, h, g, d), dtype) * std,
        "wk": jax.random.normal(k2, (d_model, h, d), dtype) * std,
        "wv": jax.random.normal(k3, (d_model, h, d), dtype) * std,
        "wo": jax.random.normal(k4, (h, g, d, d_model), dtype) * std,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, g, d), dtype)
        p["bk"] = jnp.zeros((h, d), dtype)
        p["bv"] = jnp.zeros((h, d), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((d,), dtype)
        p["k_norm"] = jnp.ones((d,), dtype)
    return p


def qkv(params: dict, x: jax.Array, cfg: AttentionConfig,
        positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns q (B,S,KV,G,D), k (B,S,KV,D), v (B,S,KV,D), RoPE'd."""
    q = jnp.einsum("bsm,mkgd->bskgd", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsm,mkd->bskd", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsm,mkd->bskd", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# AQUA projection helpers
# ---------------------------------------------------------------------------


def project_q(q: jax.Array, proj: Optional[jax.Array]) -> jax.Array:
    if proj is None:
        return q
    return jnp.einsum("bskgd,kde->bskge", q, proj.astype(q.dtype))


def project_k(k: jax.Array, proj: Optional[jax.Array]) -> jax.Array:
    if proj is None:
        return k
    return jnp.einsum("bskd,kde->bske", k, proj.astype(k.dtype))


def _aqua_project(q, k, aqua: Optional[AquaConfig], proj, head_dim: int):
    """Project + statically slice q̂ and k̂ per AQUA config (no mask — the
    magnitude mask is only materialized for the masked-dense backends; the
    block-sparse kernels do their own selection)."""
    if aqua is None or not aqua.enabled:
        return q, k
    qh = project_q(q, proj)
    kh = project_k(k, proj)
    kept = aqua.kept_dims(head_dim)
    return qh[..., :kept], kh[..., :kept]


def _aqua_mask(qh, aqua: AquaConfig, head_dim: int):
    return aqua_lib.magnitude_mask(qh, aqua.topk_dims(head_dim),
                                   block_dims=aqua.block_dims)


def _chunk_tile_mask(qh, aqua: AquaConfig, q_blk: int,
                     lengths: Optional[jax.Array]):
    """Per-*tile* dim-block mask reproducing the block-sparse kernel's
    chunk-aggregated selection (``aqua.chunk_topk_block_indices``) on the
    reference layout: all ``q_blk`` queries of a tile share the block set
    their summed |q̂| picks. The chunked-prefill serve path uses this so a
    chunk's selection equals the monolithic kernel invocation's for tiles
    at the same anchor (the engine keeps chunk cursors q_blk-aligned —
    ``REASON_CHUNK_GEOMETRY`` gates geometries where it can't).

    qh: (B, T, KV, G, D) projected (sliced) queries; lengths: (B,) valid
    rows (padding is excluded from the aggregation, as in the kernel
    wrapper). Returns a 0/1 mask shaped like ``qh``.
    """
    from repro.kernels.ops import round_k_dims
    b, t, kvh, g, d = qh.shape
    bd = aqua.block_dims
    if lengths is None:
        lengths = jnp.full((b,), t, jnp.int32)
    qf = qh.transpose(0, 2, 3, 1, 4).reshape(b, kvh * g, t, d)
    tpad = _ceil_to(t, q_blk)
    if tpad != t:
        qf = jnp.pad(qf, ((0, 0), (0, 0), (0, tpad - t), (0, 0)))
    k_dims = round_k_dims(d, aqua.k_ratio, bd)
    bidx = aqua_lib.chunk_topk_block_indices(qf, k_dims, bd, q_blk, lengths)
    nb = d // bd
    bmask = jnp.zeros((b, kvh * g, tpad // q_blk, nb), qh.dtype)
    bmask = jnp.put_along_axis(bmask, bidx, 1.0, axis=-1, inplace=False)
    mask = jnp.repeat(bmask, bd, axis=-1)                 # (B, H, NQC, D)
    mask = jnp.repeat(mask[:, :, :, None, :], q_blk, axis=3)
    mask = mask.reshape(b, kvh * g, tpad, d)[:, :, :t]
    return mask.reshape(b, kvh, g, t, d).transpose(0, 3, 1, 2, 4)


# ---------------------------------------------------------------------------
# Mesh-native attention: shard_map-wrapped cores for every backend.
#
# Per-(batch, kv-head) attention is embarrassingly parallel — the softmax
# runs over the slot/sequence axis, which every shard holds in full — so
# under a (data × model) serving mesh both the masked-dense jnp cores and
# the Pallas block-sparse kernels partition lanes over the data axes and
# KV heads over the model axis with *zero* collectives inside the wrapped
# region. Wrapping in shard_map (instead of leaving GSPMD to infer — or,
# for Pallas, silently all-gather at the opaque kernel boundary) pins
# that layout: the KV cache never gathers, the scalar-prefetched
# block-index tables are computed per shard, and the only model-axis
# communication in a step is the reduce for the output projection,
# outside the core.
#
# The mesh is installed around *trace time* by the serving engine
# (``use_decode_mesh``); compiled executables bake it in, so concurrent
# single-device engines in the same process are unaffected.
# ---------------------------------------------------------------------------

_DECODE_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "aqua_decode_mesh", default=None)
_FALLBACK_SINK: contextvars.ContextVar = contextvars.ContextVar(
    "aqua_mesh_fallback_sink", default=None)


def decode_mesh():
    return _DECODE_MESH.get()


@contextlib.contextmanager
def use_decode_mesh(mesh, fallback_sink=None):
    """Install ``mesh`` as the decode-sharding mesh for calls traced inside
    this context (no-op when ``mesh`` is None).

    Backed by ``contextvars.ContextVar`` rather than module globals:
    nested contexts in one process (engine-in-engine tests, ``--verify``
    solo replays) restore their *own* predecessor value on exit instead of
    whatever a sibling left behind, and concurrent engines on other
    threads / pytest workers never observe each other's mesh.

    ``fallback_sink``: a caller-owned set that receives the
    (backend, mode, reason) key of every mesh-kernel fallback traced in
    this context, and keys the once-per-sink warning dedup — the serving
    engine passes its own set so each engine surfaces and owns its
    fallbacks regardless of what other engines in the process did."""
    t_mesh = _DECODE_MESH.set(mesh)
    t_sink = _FALLBACK_SINK.set(fallback_sink)
    try:
        yield
    finally:
        _FALLBACK_SINK.reset(t_sink)
        _DECODE_MESH.reset(t_mesh)


# Hierarchical token sparsity rides the same trace-time installation
# pattern as the mesh: the engine resolves ``SparsitySpec.kept_pages``
# once at construction and installs (kept_pages, pin_recent_pages) around
# its jitted calls; the paged decode product picks it up and builds the
# step's ``SelectionPlan``. Baked into compiled executables like the
# mesh, so concurrent engines with different ratios coexist.
_TOKEN_SPARSITY: contextvars.ContextVar = contextvars.ContextVar(
    "aqua_token_sparsity", default=None)


def token_sparsity():
    """The installed (kept_pages, pin_recent_pages) tuple, or None."""
    return _TOKEN_SPARSITY.get()


@contextlib.contextmanager
def use_token_sparsity(kept_pages, pin_recent_pages=2):
    """Install stage-1 page participation for calls traced inside this
    context (no-op when ``kept_pages`` is None — every page participates).
    ``kept_pages`` is the per-lane participating-page count
    (``SparsitySpec.kept_pages(pages_per_lane)``)."""
    tok = _TOKEN_SPARSITY.set(
        None if kept_pages is None else (int(kept_pages),
                                         int(pin_recent_pages)))
    try:
        yield
    finally:
        _TOKEN_SPARSITY.reset(tok)


# Process-wide aggregate of mesh-fallback events (in addition to any
# per-engine sink), explicitly resettable by test fixtures so warning
# assertions don't depend on suite execution order (the previous
# ``functools.lru_cache`` dedup made them order-dependent). Warning
# *emission* dedups per sink — i.e. per engine — when one is installed.
_MESH_FALLBACK_WARNED: set = set()


def reset_mesh_fallback_warnings() -> None:
    """Clear the process-wide fallback aggregate (test fixtures)."""
    _MESH_FALLBACK_WARNED.clear()


def mesh_fallback_events() -> Tuple[Tuple[str, str, str], ...]:
    """(backend, mode, reason) keys warned process-wide since the last
    reset. Engines expose their own per-engine view
    (``ContinuousBatchingEngine.mesh_fallback_events``) — prefer that for
    asserting a specific engine really served the kernel path."""
    return tuple(sorted(_MESH_FALLBACK_WARNED))


def _log_mesh_kernel_fallback(backend_name: str, mode: str,
                              reason: str = "") -> None:
    key = (backend_name, mode, reason)
    sink = _FALLBACK_SINK.get()
    dedup = _MESH_FALLBACK_WARNED if sink is None else sink
    already = key in dedup
    # the process aggregate records every traced fallback unconditionally —
    # a reset must never be masked by an engine sink that already holds
    # the key (the dedup below only gates warning *emission*)
    _MESH_FALLBACK_WARNED.add(key)
    if already:
        return
    if sink is not None:
        sink.add(key)
    logger.warning(
        "attention backend %r: %s is falling back to the shard_map/jnp "
        "reference path for mesh-native serving%s",
        backend_name, mode, f" ({reason})" if reason else "")


def _masked_dense_decode_core(qq: jax.Array, k: jax.Array, v: jax.Array,
                              positions: jax.Array, count: jax.Array,
                              *, head_dim: int, window: Optional[int]
                              ) -> Tuple[jax.Array, jax.Array]:
    """Reference decode core on cache leaves. qq (B, KV, G, Dk) —
    magnitude-masked when AQUA is on; k (B, KV, S, Dk); v (B, KV, S, Dv);
    positions (B, S); count (B,). Returns (out (B, KV, G, Dv),
    weights (B, KV, G, S) for H2O accumulation)."""
    scores = jnp.einsum("bkgd,bksd->bkgs", qq, k.astype(qq.dtype))
    scores = scores.astype(jnp.float32) / jnp.sqrt(float(head_dim))
    vm = kv.valid_mask_from(positions, count, window=window)
    scores = jnp.where(vm[:, None, None, :], scores, NEG_INF)
    weights = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", weights.astype(v.dtype), v)
    return out, weights


def _shard_mapped_decode_core(mesh, qq, k, v, positions, count, *,
                              head_dim: int, window: Optional[int]):
    """Run the masked-dense decode core under shard_map on ``mesh``:
    lanes (batch) over the data axes, KV heads over ``model``, softmax
    axis intact per shard. Falls back to the plain core when neither axis
    divides its mesh extent (the specs sanitize to fully-replicated)."""
    from jax.experimental.shard_map import shard_map

    from repro.distributed import sharding as dsh

    b, kvh = qq.shape[0], qq.shape[1]
    dp = dsh.data_axes(mesh) or None
    row = dsh.sanitize(jax.sharding.PartitionSpec(dp, "model"),
                       (b, kvh), mesh)
    batch_ax, kv_ax = row[0], row[1]
    core = functools.partial(_masked_dense_decode_core, head_dim=head_dim,
                             window=window)
    if batch_ax is None and kv_ax is None:
        return core(qq, k, v, positions, count)
    P = jax.sharding.PartitionSpec
    head4 = P(batch_ax, kv_ax, None, None)
    return shard_map(
        core, mesh=mesh,
        in_specs=(head4, head4, head4, P(batch_ax, None), P(batch_ax)),
        out_specs=(head4, head4),
        check_rep=False,
    )(qq, k, v, positions, count)


# ---------------------------------------------------------------------------
# Mesh-native Pallas kernels: shard_map-wrapped block-sparse prefill and
# decode. A raw ``pl.pallas_call`` is opaque to the SPMD partitioner — a
# sharded operand would silently all-gather at the kernel boundary — so
# the kernel wrappers run *inside* shard_map on shard-local shapes: lanes
# (batch) partition over the data axes and KV heads over ``model`` (the
# query groups and the whole dim-blocks of the dim-major K̂ layout ride
# with their KV head, so every model shard streams whole dim-blocks). The
# magnitude top-k block-index tables are computed per shard, mirroring
# ``_shard_mapped_decode_core``: no collectives inside the mapped region.
# An axis whose extent doesn't divide its dimension sanitizes to
# replicated (B=1 admission prefills, MQA's single KV head); batches that
# would leave the cache slot-sharded keep the jnp reference path — see
# ``distributed.sharding.kernel_shardable``.
# ---------------------------------------------------------------------------


def _kernel_row_axes(mesh, batch: int, kv_heads: int):
    """(batch_axis, kv_axis) for the kernel shard_map: lanes over the data
    axes, KV heads over ``model``; an axis whose mesh extent doesn't divide
    its dimension sanitizes to None (replicated)."""
    from repro.distributed import sharding as dsh

    dp = dsh.data_axes(mesh) or None
    row = dsh.sanitize(jax.sharding.PartitionSpec(dp, "model"),
                       (batch, kv_heads), mesh)
    return row[0], row[1]


def shard_mapped_prefill_kernel(mesh, backend, qq, kk, v, *, cfg, aqua,
                                positions, lengths, causal):
    """Run a Pallas prefill backend under shard_map on ``mesh``.

    qq (B, S, KV, G, Dk) / kk (B, S, KV, Dk) / v (B, S, KV, Dv) in model
    layout; ``positions`` must be 1-D (2-D tables route to the dense
    reference before dispatch). Returns (out (B, S, KV, G, Dv), None) —
    kernel backends produce no dense weights."""
    from jax.experimental.shard_map import shard_map

    b, s, kvh = qq.shape[0], qq.shape[1], qq.shape[2]
    batch_ax, kv_ax = _kernel_row_axes(mesh, b, kvh)
    if lengths is None:
        # materialize full lengths so the shard_map signature is static
        lengths = jnp.full((b,), s, jnp.int32)

    def core(qs, ks, vs, pos, ls):
        out, _ = backend.prefill(qs, ks, vs, cfg=cfg, aqua=aqua,
                                 positions=pos, lengths=ls, causal=causal)
        return out

    # Even fully-replicated rows (B=1 MQA) stay inside shard_map: a raw
    # pallas_call in the jitted step would face the SPMD partitioner —
    # the exact hazard this wrapper exists to remove.
    P = jax.sharding.PartitionSpec
    out = shard_map(
        core, mesh=mesh,
        in_specs=(P(batch_ax, None, kv_ax, None, None),
                  P(batch_ax, None, kv_ax, None),
                  P(batch_ax, None, kv_ax, None),
                  P(None), P(batch_ax)),
        out_specs=P(batch_ax, None, kv_ax, None, None),
        check_rep=False,
    )(qq, kk, v, positions, lengths)
    return out, None


def shard_mapped_decode_kernel(mesh, backend, q, cache, *, cfg, aqua):
    """Decode twin of :func:`shard_mapped_prefill_kernel`: the block-sparse
    decode kernel on shard-local cache leaves. q (B, KV, G, Dk); the slot
    axis stays whole per shard (the kernel streams full dim-major sequence
    stripes), so per-shard ``NB_sel``/``NB_total`` accounting equals the
    global one. Returns (B, KV, G, Dv)."""
    from jax.experimental.shard_map import shard_map

    b, kvh = q.shape[0], q.shape[1]
    batch_ax, kv_ax = _kernel_row_axes(mesh, b, kvh)

    def core(qs, ks, vs, pos, cnt, acc):
        local = kv.AttnCache(k=ks, v=vs, positions=pos, count=cnt,
                             acc_score=acc)
        return backend.decode(qs, local, cfg=cfg, aqua=aqua)

    P = jax.sharding.PartitionSpec
    head4 = P(batch_ax, kv_ax, None, None)
    return shard_map(
        core, mesh=mesh,
        in_specs=(head4, head4, head4, P(batch_ax, None), P(batch_ax),
                  P(batch_ax, kv_ax, None)),
        out_specs=head4,
        check_rep=False,
    )(q, cache.k, cache.v, cache.positions, cache.count, cache.acc_score)


def shard_mapped_paged_decode_kernel(mesh, backend, q, cache, *, cfg, aqua,
                                     part_idx=None):
    """Paged twin of :func:`shard_mapped_decode_kernel`: the block-sparse
    paged decode kernel on shard-local pool + page-table leaves.

    The partitioning follows :func:`distributed.sharding.decode_state_pspec`'s
    paged branch exactly: the page *pool* (k/v/pos/acc) replicates over the
    data axes — pages are lane-global, any lane may map any physical page,
    so table entries are pool-global ids valid unchanged on every data
    shard — while its KV-head axis shards over ``model`` (whole dim-blocks
    and whole pages ride with their head). The page-*table* rows partition
    with their lanes over the data axes, so each data shard's kernel
    invocation scalar-prefetches only its own lane group's table rows and
    dereferences them against its full (KV-sharded) pool slice inside the
    ``index_map`` — zero collectives inside the mapped region, exactly like
    the contiguous kernel threads its dim-block indices. q (B, KV, G, Dk);
    returns (B, KV, G, Dv).

    ``part_idx`` (B, KP): hierarchical stage-1 participating-page table.
    It MUST be computed *outside* this wrapper (``core.selection`` on the
    global arrays) — the acc_pool is KV-sharded over ``model``, so a
    shard-local page ranking would give each model shard a different
    participating set. The finished table partitions with its lanes over
    the data axes exactly like the page table
    (``distributed.sharding.page_rank_pspec``) and its entries are
    per-lane logical indices, so each shard's kernel invocation
    scalar-prefetches its own lane group's rows unchanged."""
    from jax.experimental.shard_map import shard_map

    b, kvh = q.shape[0], q.shape[1]
    batch_ax, kv_ax = _kernel_row_axes(mesh, b, kvh)

    P = jax.sharding.PartitionSpec
    head4 = P(batch_ax, kv_ax, None, None)
    pool4 = P(None, kv_ax, None, None)
    in_specs = [head4, pool4, pool4, P(None, None), P(None, kv_ax, None),
                P(batch_ax, None), P(batch_ax)]
    operands = [q, cache.k_pool, cache.v_pool, cache.pos_pool,
                cache.acc_pool, cache.page_table, cache.count]
    quant = cache.k_scale is not None
    if quant:
        # per-page quant scales partition with their pages' KV heads over
        # `model` (page axis whole, like the pool); one-scale-per-page
        # (SH=1) arrives replicated — the head slice is then a no-op.
        sh = cache.k_scale.shape[1]
        scale_spec = P(None, kv_ax if sh > 1 else None)
        in_specs += [scale_spec, scale_spec]
        operands += [cache.k_scale, cache.v_scale]
    hier = part_idx is not None
    if hier:
        in_specs.append(P(batch_ax, None))
        operands.append(part_idx)

    def core(qs, kp, vp, pp, ap, pt, cnt, *rest):
        rest = list(rest)
        part = rest.pop() if hier else None
        ks, vs = rest if quant else (None, None)
        local = kv.PagedAttnCache(k_pool=kp, v_pool=vp, pos_pool=pp,
                                  acc_pool=ap, page_table=pt, count=cnt,
                                  k_scale=ks, v_scale=vs)
        if part is None:
            return backend.paged_decode(qs, local, cfg=cfg, aqua=aqua)
        return backend.paged_decode(qs, local, cfg=cfg, aqua=aqua,
                                    part_idx=part)

    return shard_map(
        core, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=head4,
        check_rep=False,
    )(*operands)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — pure-XLA memory-efficient path used for
# long-sequence prefill; the S×S score matrix never materializes. On real
# TPU this role is played by kernels/flash_attention.py; the jnp version
# keeps the dry-run/compile path portable and GSPMD-shardable.
# ---------------------------------------------------------------------------

CHUNKED_THRESHOLD = 2048  # use chunked path for sequences >= this


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      head_dim: int, causal: bool = True,
                      window: Optional[int] = None, q_blk: int = 512,
                      k_blk: int = 1024,
                      lengths: Optional[jax.Array] = None) -> jax.Array:
    """q: (B, S, KV, G, D'); k: (B, S, KV, D'); v: (B, S, KV, Dv).

    Online-softmax double scan over (q blocks × k blocks). Scale uses the
    FULL head_dim (AQUA approximates full scores). ``lengths`` (B,) masks
    ragged rows per key block. Returns (B, S, KV, G, Dv).
    """
    b, s, kvh, g, d = q.shape
    dv = v.shape[-1]
    q_blk, k_blk = _rtf.attn_blocks(q_blk, k_blk)
    q_blk = min(q_blk, s)
    k_blk = min(k_blk, s)
    s_real = s
    pad = (-s) % math.lcm(q_blk, k_blk)
    if pad:
        # non-divisible S: pad the sequence and mask the tail via the
        # lengths mechanism (covers causal and non-causal alike); padded
        # query rows are sliced off below
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s += pad
        if lengths is None:
            lengths = jnp.full((b,), s_real, jnp.int32)
    nq, nk = s // q_blk, s // k_blk
    scale = 1.0 / (float(head_dim) ** 0.5)

    qb = q.reshape(b, nq, q_blk, kvh, g, d).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(b, nk, k_blk, kvh, d).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, k_blk, kvh, dv).transpose(1, 0, 3, 2, 4)

    # Window-band restriction (§Perf iteration): for sliding-window
    # attention only the k-blocks intersecting the (window+q_blk) band
    # around the diagonal contribute; iterate exactly those (compute and
    # HBM bytes scale with the window, not the context). For full causal
    # attention iterate the causal prefix of k-blocks per q-block.
    band = None
    if causal and window is not None and window < s:
        band = min(nk, (q_blk + window) // k_blk + 2)

    def outer(_, qi_idx):
        qi, iq = qi_idx                     # (B,KV,G,qb,D), scalar

        def step(c, kj, vj, jk, valid):
            m, l, acc = c
            sij = jnp.einsum("bkgqd,bktd->bkgqt", qi.astype(jnp.float32),
                             kj.astype(jnp.float32)) * scale
            qpos = iq * q_blk + jnp.arange(q_blk)[:, None]
            kpos = jk * k_blk + jnp.arange(k_blk)[None, :]
            mask = jnp.ones((q_blk, k_blk), bool)
            if causal:
                mask &= qpos >= kpos
            if window is not None:
                mask &= kpos > qpos - window
            mask &= valid
            mask = mask[None]                        # (1, q_blk, k_blk)
            if lengths is not None:
                mask = mask & (kpos[None] < lengths[:, None, None])
            sij = jnp.where(mask[:, None, None], sij, NEG_INF)
            m_new = jnp.maximum(m, sij.max(-1))
            p = jnp.exp(sij - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqt,bktd->bkgqd", p, vj.astype(jnp.float32))
            return (m_new, l, acc)

        init = (jnp.full((b, kvh, g, q_blk), NEG_INF, jnp.float32),
                jnp.zeros((b, kvh, g, q_blk), jnp.float32),
                jnp.zeros((b, kvh, g, q_blk, dv), jnp.float32))

        if band is not None:
            last = ((iq + 1) * q_blk - 1) // k_blk  # last needed k-block

            def inner_band(c, j):
                raw = last - (band - 1) + j         # may be < 0 early on
                idx = jnp.clip(raw, 0, nk - 1)
                kj = jax.lax.dynamic_index_in_dim(kb, idx, 0, False)
                vj = jax.lax.dynamic_index_in_dim(vb, idx, 0, False)
                return step(c, kj, vj, idx, raw >= 0), None
            (m, l, acc), _ = _scan(inner_band, init,
                                   jnp.arange(band))
        else:
            def inner(c, kj_idx):
                kj, vj, jk = kj_idx
                return step(c, kj, vj, jk, True), None
            (m, l, acc), _ = _scan(
                inner, init, (kb, vb, jnp.arange(nk)))
        return None, acc / jnp.maximum(l, 1e-30)[..., None]

    _, ob = _scan(outer, None, (qb, jnp.arange(nq)))
    # (nq, B, KV, G, q_blk, Dv) -> (B, S, KV, G, Dv)
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, kvh, g, dv)
    return out[:, :s_real]


# ---------------------------------------------------------------------------
# Attention backend registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttentionBackend:
    """One registry entry (see the module docstring for the contract).

    ``requires_pallas`` backends fall back to the masked-dense reference
    when Pallas is unavailable; ``aqua_native`` backends additionally need
    calibrated AQUA projections (they consume unmasked q̂/k̂).
    ``paged_decode`` (optional) is the decode entry for the block-paged
    KV pool: same query contract as ``decode`` but over a
    ``kv.PagedAttnCache`` (pool + per-lane page table) instead of the
    contiguous slot cache.
    """

    name: str
    prefill: Callable[..., Tuple[jax.Array, Optional[jax.Array]]]
    decode: Optional[Callable[..., jax.Array]] = None
    paged_decode: Optional[Callable[..., jax.Array]] = None
    requires_pallas: bool = False
    aqua_native: bool = False


_BACKENDS: Dict[str, AttentionBackend] = {}


def register_backend(backend: AttentionBackend) -> AttentionBackend:
    _BACKENDS[backend.name] = backend
    return backend


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


def get_backend(name: str) -> AttentionBackend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown attention backend {name!r}; "
                       f"available: {available_backends()}") from None


def resolve_backend(name: str = "auto",
                    aqua: Optional[AquaConfig] = None) -> AttentionBackend:
    """Map a config-selected backend name to a runnable backend.

    ``auto`` prefers the Pallas kernels when they would run compiled (on
    TPU, or forced via ``runtime_flags.PALLAS_OVERRIDE``) and the jnp
    references otherwise. Explicitly requested kernel backends run in
    interpret mode off-TPU, but fall back to the masked-dense reference
    when Pallas is unavailable; AQUA-native backends fall back to flash /
    dense when AQUA is disabled (no projections to select over).
    """
    aqua_on = aqua is not None and aqua.enabled
    if name in (None, "", "auto"):
        if _rtf.kernels_preferred():
            name = "aqua-block-sparse" if aqua_on else "flash"
        else:
            name = "aqua-masked-dense" if aqua_on else "dense-jnp"
    be = get_backend(name)
    if be.requires_pallas and not _rtf.pallas_available():
        be = get_backend("aqua-masked-dense" if aqua_on else "dense-jnp")
    if be.aqua_native and not aqua_on:
        be = get_backend("flash" if _rtf.kernels_preferred() else "dense-jnp")
    return be


def _dense_jnp_prefill(qq, kk, v, *, cfg, aqua, positions, lengths, causal):
    """Materialized-score reference; switches to the chunked online-softmax
    scan for long causal sequences (the S×S matrix never materializes)."""
    s = qq.shape[1]
    if s >= CHUNKED_THRESHOLD and causal and positions.ndim == 1:
        out = chunked_attention(qq, kk, v, head_dim=cfg.head_dim,
                                causal=True, window=cfg.window,
                                lengths=lengths)
        return out, None
    scores = jnp.einsum("bskgd,btkd->bkgst", qq, kk)
    scores = scores.astype(jnp.float32) / jnp.sqrt(float(cfg.head_dim))
    kpos = positions if positions.ndim == 2 else positions[None]
    mask = None
    if causal:
        qpos = kpos
        mask = qpos[:, None, None, :, None] >= kpos[:, None, None, None, :]
        if cfg.window is not None:
            mask &= (kpos[:, None, None, None, :]
                     > qpos[:, None, None, :, None] - cfg.window)
    if lengths is not None:
        lmask = kpos[:, None, None, None, :] < lengths[:, None, None, None,
                                                       None]
        mask = lmask if mask is None else mask & lmask
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    weights = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", weights.astype(v.dtype), v)
    return out, weights


def _flash_prefill(qq, kk, v, *, cfg, aqua, positions, lengths, causal):
    """Pallas flash kernel on head-major layout. Ragged lengths, 2-D
    positions, non-causal shapes (sequence padding is only safe under a
    causal mask) and AQUA-Memory-sliced heads (the kernel assumes
    dk == dv) are delegated to the dense reference."""
    if (not causal or positions.ndim == 2 or lengths is not None
            or qq.shape[-1] != v.shape[-1]):
        return _dense_jnp_prefill(qq, kk, v, cfg=cfg, aqua=aqua,
                                  positions=positions, lengths=lengths,
                                  causal=causal)
    from repro.kernels import ops as kops
    b, s, kvh, g, d = qq.shape
    dv = v.shape[-1]
    qf = qq.transpose(0, 2, 3, 1, 4).reshape(b, kvh * g, s, d)
    kf = kk.transpose(0, 2, 1, 3)
    vf = v.transpose(0, 2, 1, 3)
    blk = min(128, _ceil_to(s, 8))
    spad = _ceil_to(s, blk)
    if spad != s:
        pad = ((0, 0), (0, 0), (0, spad - s), (0, 0))
        qf, kf, vf = jnp.pad(qf, pad), jnp.pad(kf, pad), jnp.pad(vf, pad)
    of = kops.flash_attention(qf, kf, vf, causal=True, window=cfg.window,
                              q_blk=blk, k_blk=blk)[:, :, :s]
    out = of.reshape(b, kvh, g, s, dv).transpose(0, 3, 1, 2, 4)
    return out, None


def _aqua_block_sparse_prefill(qh, kh, v, *, cfg, aqua, positions, lengths,
                               causal):
    """AQUA block-sparse chunked-prefill kernel: per-chunk dim-block
    selection over unmasked q̂, dim-major K̂ streaming (kernels/aqua_prefill).
    Scores are scaled by the FULL head_dim — the paper approximates full
    scores even when k̂ is statically sliced."""
    from repro.kernels import ops as kops
    b, s, kvh, g, dk = qh.shape
    dv = v.shape[-1]
    bd = aqua.block_dims
    qf = qh.transpose(0, 2, 3, 1, 4).reshape(b, kvh * g, s, dk)
    kf = kh.transpose(0, 2, 1, 3)
    vf = v.transpose(0, 2, 1, 3)
    of = kops.aqua_prefill(qf, kf, vf, lengths, k_ratio=aqua.k_ratio,
                           block_dims=bd, q_blk=aqua.prefill_q_blk,
                           k_blk=aqua.prefill_k_blk, causal=causal,
                           window=cfg.window,
                           scale=1.0 / float(cfg.head_dim) ** 0.5)
    out = of.reshape(b, kvh, g, s, dv).transpose(0, 3, 1, 2, 4)
    return out, None


def _aqua_block_sparse_decode(q_hat, cache, *, cfg, aqua):
    """AQUA block-sparse decode kernel over the contiguous slot cache.
    q_hat: (B, KV, G, Dk) projected (unmasked) query. Returns
    (B, KV, G, Dv)."""
    from repro.kernels import ops as kops
    b, kvh, g, dk = q_hat.shape
    bd = aqua.block_dims
    qf = q_hat.reshape(b, kvh * g, dk)
    lengths = jnp.minimum(cache.count, cache.num_slots)
    seq_blk = min(aqua.decode_seq_blk, _ceil_to(cache.num_slots, 8))
    out = kops.aqua_decode(qf, cache.k, cache.v, lengths,
                           k_ratio=aqua.k_ratio, block_dims=bd,
                           seq_blk=seq_blk,
                           scale=1.0 / float(cfg.head_dim) ** 0.5)
    return out.reshape(b, kvh, g, -1)


def _aqua_block_sparse_paged_decode(q_hat, cache: kv.PagedAttnCache, *,
                                    cfg, aqua, part_idx=None):
    """Paged AQUA block-sparse decode: the page table rides the same
    scalar-prefetch ``index_map`` machinery as the dim-block selection
    (kernels/aqua_decode.aqua_paged_decode_attention) — pool pages stream
    HBM→VMEM directly, no gathered lane view is ever materialized.
    ``part_idx`` (B, KP) is the hierarchical stage-1 participating-page
    table (``core.selection``), or None to walk every page."""
    from repro.kernels import ops as kops
    b, kvh, g, dk = q_hat.shape
    qf = q_hat.reshape(b, kvh * g, dk)
    lengths = jnp.minimum(cache.count, cache.num_slots)
    out = kops.aqua_paged_decode(qf, cache.k_pool, cache.v_pool,
                                 cache.page_table, lengths,
                                 cache.k_scale, cache.v_scale, part_idx,
                                 k_ratio=aqua.k_ratio,
                                 block_dims=aqua.block_dims,
                                 seq_blk=aqua.decode_seq_blk,
                                 scale=1.0 / float(cfg.head_dim) ** 0.5)
    return out.reshape(b, kvh, g, -1)


register_backend(AttentionBackend("dense-jnp", _dense_jnp_prefill))
register_backend(AttentionBackend("flash", _flash_prefill,
                                  requires_pallas=True))
register_backend(AttentionBackend("aqua-masked-dense", _dense_jnp_prefill))
register_backend(AttentionBackend("aqua-block-sparse",
                                  _aqua_block_sparse_prefill,
                                  decode=_aqua_block_sparse_decode,
                                  paged_decode=_aqua_block_sparse_paged_decode,
                                  requires_pallas=True, aqua_native=True))


# ---------------------------------------------------------------------------
# Prefill attention (full sequence)
# ---------------------------------------------------------------------------


def prefill_attention(params: dict, x: jax.Array, cfg: AttentionConfig,
                      aqua: Optional[AquaConfig] = None,
                      proj: Optional[jax.Array] = None,
                      positions: Optional[jax.Array] = None,
                      kv_x: Optional[jax.Array] = None,
                      return_aux: bool = False,
                      lengths: Optional[jax.Array] = None):
    """Sequence attention, dispatched through the backend registry
    (``cfg.backend``). ``kv_x`` enables cross-attention (keys/values from
    the encoder); in that mode AQUA and causal masking are bypassed unless
    configured otherwise. ``lengths`` (B,) masks ragged rows: keys at or
    beyond a row's length are never attended.

    Returns out (B, S, d_model) [, aux dict with q/k activations & weights].
    """
    b, s, _ = x.shape
    if kv_x is not None and lengths is not None:
        raise ValueError(
            "`lengths` masks self-attention keys; ragged cross-attention "
            "would need encoder-side lengths (unsupported)")
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    src = x if kv_x is None else kv_x

    q = jnp.einsum("bsm,mkgd->bskgd", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsm,mkd->bskd", src, params["wk"].astype(src.dtype))
    v = jnp.einsum("bsm,mkd->bskd", src, params["wv"].astype(src.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if cfg.use_rope and kv_x is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    aqua_on = aqua is not None and aqua.enabled
    qh, kh = _aqua_project(q, k, aqua, proj, cfg.head_dim)

    causal = cfg.causal and kv_x is None
    backend = resolve_backend(cfg.backend, aqua=aqua)
    if kv_x is not None or positions.ndim == 2:
        # cross-attention / per-row position tables: reference path only
        backend = get_backend("dense-jnp")
    if backend.name == "aqua-block-sparse":
        # The kernel needs dim-*block* selection; block_dims=1 is the
        # paper's per-dim semantics — never silently coarsen it (numerics
        # must not depend on which backend a platform resolved to). The
        # masked-q identity is exact over masked inputs, so on TPU the
        # flash kernel serves per-dim selection at identical numerics
        # without materializing S×S scores; jnp reference elsewhere.
        if (not aqua_on or aqua.block_dims <= 1
                or kh.shape[-1] % aqua.block_dims != 0):
            backend = get_backend("flash" if _rtf.kernels_preferred()
                                  else "aqua-masked-dense")
    kernel_mesh = None
    if backend.requires_pallas and decode_mesh() is not None:
        # mesh-native serving: run the Pallas kernel under shard_map
        # (lanes × KV heads, per-shard block-index tables); only axis
        # extents that would leave the cache slot-sharded keep the
        # GSPMD-shardable jnp reference path
        from repro.distributed import sharding as dsh
        if dsh.kernel_shardable(decode_mesh(), cfg,
                                aqua if backend.aqua_native else None,
                                batch=b):
            kernel_mesh = decode_mesh()
        else:
            _log_mesh_kernel_fallback(backend.name, "prefill",
                                      REASON_NONDIVISIBLE_MESH)
            backend = get_backend("aqua-masked-dense" if aqua_on
                                  else "dense-jnp")
    if backend.name == "aqua-block-sparse":
        qq, kk = qh, kh          # unmasked: kernel selects dim-blocks
    elif aqua_on:
        # masked-q identity: per-query magnitude mask, materialized only
        # on the reference paths (the kernels select inside the wrapper)
        qq, kk = qh * _aqua_mask(qh, aqua, cfg.head_dim), kh
    else:
        qq, kk = q, k

    if kernel_mesh is not None:
        out, weights = shard_mapped_prefill_kernel(
            kernel_mesh, backend, qq, kk, v, cfg=cfg, aqua=aqua,
            positions=positions, lengths=lengths, causal=causal)
    else:
        out, weights = backend.prefill(qq, kk, v, cfg=cfg, aqua=aqua,
                                       positions=positions, lengths=lengths,
                                       causal=causal)
    out = out.astype(v.dtype)
    out = jnp.einsum("bskgd,kgdm->bsm", out, params["wo"].astype(x.dtype))
    if return_aux:
        aux = {"q": q, "k": k, "weights": weights,
               "q_hat": qh if aqua_on else None,
               "k_hat": kh if aqua_on else None}
        return out, aux
    return out


# ---------------------------------------------------------------------------
# Prefill -> cache handoff
# ---------------------------------------------------------------------------


def build_cache_from_prefill(params: dict, x: jax.Array, cfg: AttentionConfig,
                             aqua: Optional[AquaConfig],
                             proj: Optional[jax.Array],
                             max_seq: int,
                             lengths: Optional[jax.Array] = None
                             ) -> kv.AttnCache:
    """Construct the decode cache after a prefill pass (serving engine).

    ``lengths`` (B,) marks ragged rows: their ``count`` starts at the valid
    prefix length, so decode masks the padding keys and the next token
    lands at the right position/slot. Only the contiguous full-cache
    policy places ragged rows coherently — window rings and H2O eviction
    place slots assuming a rectangular batch, so combining them with
    ``lengths`` raises rather than silently corrupting generations.
    """
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    q, k, v = qkv(params, x, cfg, positions)
    head_dim = cfg.head_dim
    if aqua is not None and aqua.enabled:
        k = project_k(k, proj)[..., :aqua.kept_dims(head_dim)]
    dk, dv = k.shape[-1], v.shape[-1]

    h2o_budget = None
    if aqua is not None and aqua.h2o_ratio < 1.0:
        h2o_budget = max(8, int(aqua.h2o_ratio * max_seq))
    if lengths is not None and (cfg.window is not None
                                or h2o_budget is not None):
        raise ValueError(
            "ragged `lengths` require the contiguous full-cache policy; "
            "sliding-window and H2O caches place slots assuming a "
            "rectangular batch — prefill unpadded rows separately or drop "
            "`lengths`")
    count = jnp.full((b,), s, jnp.int32) if lengths is None else lengths
    slots = kv.cache_slots(max_seq, cfg.window, h2o_budget)
    cache = kv.init_attn_cache(b, cfg.num_kv_heads, slots, dk, dv, k.dtype)

    if h2o_budget is not None and s > slots:
        # H2O prefill: accumulated (approximate, if AQUA) attention mass.
        # NB: k above is already projected + sliced when AQUA is on, so we
        # only transform the query side here.
        qq = q
        if aqua.enabled and proj is not None:
            qq = project_q(q, proj)[..., :aqua.kept_dims(head_dim)]
            m = aqua_lib.magnitude_mask(qq, aqua.topk_dims(head_dim),
                                        block_dims=aqua.block_dims)
            qq = qq * m
        sc = jnp.einsum("bskgd,btkd->bkgst", qq, k)
        sc = sc.astype(jnp.float32) / jnp.sqrt(float(head_dim))
        causal = positions[:, None] >= positions[None, :]
        if cfg.window is not None:
            # combined H2O+window: out-of-window keys never receive mass,
            # so the heavy-hitter statistic only ranks in-window tokens
            causal &= positions[None, :] > positions[:, None] - cfg.window
        sc = jnp.where(causal[None, None, None], sc, NEG_INF)
        w = jax.nn.softmax(sc, axis=-1)
        acc = w.sum(axis=(2, 3))  # (B, KV, S) summed over groups & queries
        recent = max(1, int(aqua.h2o_recent_frac * slots))
        keep_hh = slots - recent
        score_tok = acc.sum(axis=1)  # (B, S)
        # protect the recent window from scored selection
        score_tok = score_tok.at[:, s - recent:].set(-jnp.inf)
        _, hh_idx = jax.lax.top_k(score_tok, keep_hh)
        recent_idx = jnp.broadcast_to(jnp.arange(s - recent, s), (b, recent))
        sel = jnp.concatenate([jnp.sort(hh_idx, axis=-1), recent_idx], axis=-1)
        # gather selected tokens: (S, KV, D)[sel] -> (slots, KV, D) -> (KV, slots, D)
        take = jax.vmap(lambda a, i: a[i].transpose(1, 0, 2), in_axes=(0, 0))
        cache = kv.AttnCache(
            k=take(k, sel), v=take(v, sel),
            positions=jnp.take_along_axis(
                jnp.broadcast_to(positions, (b, s)), sel, axis=-1),
            count=jnp.full((b,), s, jnp.int32),
            acc_score=jnp.take_along_axis(acc, sel[:, None, :], axis=-1),
        )
        return cache

    # full / window caches: last `slots` tokens, ring-consistent placement.
    start = max(0, s - slots)
    ring = cfg.window is not None
    tok_pos = positions[start:]
    slot_idx = (tok_pos % slots) if ring else (tok_pos - start)
    cache = kv.AttnCache(
        k=cache.k.at[:, :, slot_idx].set(k[:, start:].transpose(0, 2, 1, 3)),
        v=cache.v.at[:, :, slot_idx].set(v[:, start:].transpose(0, 2, 1, 3)),
        positions=cache.positions.at[:, slot_idx].set(tok_pos[None]),
        count=count,
        acc_score=cache.acc_score,
    )
    return cache


# ---------------------------------------------------------------------------
# Prefix-shared tail prefill (paged serving)
# ---------------------------------------------------------------------------


def prefixed_tail_attention(params: dict, x: jax.Array, cfg: AttentionConfig,
                            aqua: Optional[AquaConfig],
                            proj: Optional[jax.Array], *,
                            prefix_k: jax.Array, prefix_v: jax.Array,
                            prefix_positions: jax.Array,
                            prefix_len: jax.Array, positions: jax.Array,
                            lengths: Optional[jax.Array] = None,
                            select_q_blk: Optional[int] = None
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Causal attention of a prompt *tail* against a read-only cache
    prefix plus itself — the zero-recompute admission path for
    prefix-shared paged serving, and the per-chunk step of chunked
    prefill.

    x: (1, T, d_model) tail activations; ``prefix_k`` (1, KV, S, Dk') /
    ``prefix_v`` (1, KV, S, Dv) are the lane's gathered cache view (keys
    already projected + sliced when AQUA is on); ``prefix_positions``
    (1, S) with -1 empties; prefix keys are valid where their position is
    in ``[0, prefix_len)``. ``positions`` (1, T) absolute tail positions
    (``prefix_len + arange``); ``lengths`` (1,) masks ragged tail padding.

    Runs the masked-dense reference path (admission-time work, exactly
    like B=1 graft prefills under a mesh). ``select_q_blk`` (static)
    switches the AQUA selection from per-query to per-tile aggregation
    (:func:`_chunk_tile_mask`) — the chunked-prefill engine passes the
    kernel's ``prefill_q_blk`` there so chunks of a fresh prompt select
    exactly the dim-blocks the monolithic kernel admission would.
    Returns
    (out (1, T, d_model), k_cache (1, T, KV, Dk'), v (1, T, KV, Dv)) with
    ``k_cache`` in the cache's stored form (projected/sliced under AQUA).
    """
    q, k, v = qkv(params, x, cfg, positions)
    aqua_on = aqua is not None and aqua.enabled
    qh, kh = _aqua_project(q, k, aqua, proj, cfg.head_dim)
    if aqua_on:
        if select_q_blk is not None:
            qq = qh * _chunk_tile_mask(qh, aqua, select_q_blk, lengths)
        else:
            qq = qh * _aqua_mask(qh, aqua, cfg.head_dim)
        kk = kh
    else:
        qq, kk = q, k

    scale = 1.0 / jnp.sqrt(float(cfg.head_dim))
    qpos = positions                                     # (1, T)
    ppos = prefix_positions                              # (1, S)
    sp = jnp.einsum("bskgd,bktd->bkgst", qq, prefix_k.astype(qq.dtype))
    sp = sp.astype(jnp.float32) * scale
    mp = ((ppos >= 0) & (ppos < prefix_len))[:, None, None, None, :]
    if cfg.window is not None:
        mp = mp & (ppos[:, None, None, None, :]
                   > qpos[:, None, None, :, None] - cfg.window)
    st = jnp.einsum("bskgd,btkd->bkgst", qq, kk)
    st = st.astype(jnp.float32) * scale
    mt = qpos[:, None, None, :, None] >= qpos[:, None, None, None, :]
    if cfg.window is not None:
        mt &= qpos[:, None, None, None, :] > \
            qpos[:, None, None, :, None] - cfg.window
    if lengths is not None:
        t = q.shape[1]
        mt &= (jnp.arange(t)[None, :] < lengths[:, None]
               )[:, None, None, None, :]
    scores = jnp.concatenate([jnp.where(mp, sp, NEG_INF),
                              jnp.where(mt, st, NEG_INF)], axis=-1)
    weights = jax.nn.softmax(scores, axis=-1)
    vals = jnp.concatenate([prefix_v.astype(v.dtype),
                            v.transpose(0, 2, 1, 3)], axis=2)
    out = jnp.einsum("bkgst,bktd->bskgd", weights.astype(v.dtype), vals)
    out = jnp.einsum("bskgd,kgdm->bsm", out.astype(v.dtype),
                     params["wo"].astype(x.dtype))
    return out, kk, v


# ---------------------------------------------------------------------------
# Decode attention (single step, slot cache)
# ---------------------------------------------------------------------------


def decode_attention(params: dict, x_t: jax.Array, cache: kv.AttnCache,
                     cfg: AttentionConfig, aqua: Optional[AquaConfig] = None,
                     proj: Optional[jax.Array] = None,
                     cross: Optional[Tuple[jax.Array, jax.Array]] = None,
                     write_mask: Optional[jax.Array] = None,
                     ) -> Tuple[jax.Array, kv.AttnCache]:
    """One decode step. x_t: (B, d_model). Returns (out (B, d_model), cache).

    ``cross`` = (k_enc, v_enc) each (B, S_enc, KV, D) for cross-attention
    layers (whisper decoder); those bypass the cache entirely.

    ``write_mask`` (B,) bool freezes masked-off rows' cache (no K/V write,
    no count advance, no H2O accumulation) — the continuous-batching
    engine's inactive lanes still flow through the batched step at static
    shape but their state stays bit-identical.
    """
    b = x_t.shape[0]
    if cross is not None:
        k_enc, v_enc = cross
        q = jnp.einsum("bm,mkgd->bkgd", x_t, params["wq"].astype(x_t.dtype))
        if cfg.qkv_bias:
            q = q + params["bq"].astype(x_t.dtype)
        if cfg.qk_norm:
            q = rms_norm(q, params["q_norm"])
        sc = jnp.einsum("bkgd,bskd->bkgs", q, k_enc).astype(jnp.float32)
        w = jax.nn.softmax(sc / jnp.sqrt(float(cfg.head_dim)), axis=-1)
        out = jnp.einsum("bkgs,bskd->bkgd", w.astype(v_enc.dtype), v_enc)
        out = jnp.einsum("bkgd,kgdm->bm", out, params["wo"].astype(x_t.dtype))
        return out, cache

    pos = cache.count  # (B,) position of the incoming token
    q, k, v = qkv(params, x_t[:, None, :], cfg, pos[:, None])
    q, k_t, v_t = q[:, 0], k[:, 0], v[:, 0]  # (B,KV,G,D), (B,KV,D)

    head_dim = cfg.head_dim
    aqua_on = aqua is not None and aqua.enabled
    if aqua_on:
        qh = jnp.einsum("bkgd,kde->bkge", q, proj.astype(q.dtype))
        kh = jnp.einsum("bkd,kde->bke", k_t, proj.astype(k_t.dtype))
        kept = aqua.kept_dims(head_dim)
        q, k_t = qh[..., :kept], kh[..., :kept]

    h2o = aqua is not None and aqua.enabled and aqua.h2o_ratio < 1.0
    recent_len = 0
    if h2o:
        recent_len = max(1, int(aqua.h2o_recent_frac * cache.num_slots))
    if isinstance(cache, kv.PagedAttnCache):
        slot, evict = kv.paged_select_slot(cache, window=cfg.window, h2o=h2o,
                                           recent_len=recent_len)
        cache = kv.paged_insert(cache, slot, k_t, v_t,
                                write_mask=write_mask, evict_page=evict)
        return _paged_decode_product(params, x_t, q, cache, cfg, aqua,
                                     h2o=h2o, write_mask=write_mask)
    slot = kv.select_slot(cache, window=cfg.window, h2o=h2o,
                          recent_len=recent_len)
    cache = kv.insert(cache, slot, k_t, v_t, write_mask=write_mask)

    # Registry dispatch: the block-sparse decode kernel serves the
    # contiguous full-cache policy (no ring buffer, no eviction — those
    # need the masked-dense path's per-slot position masking / weights).
    # Under a serving mesh the kernel runs shard_mapped (lanes over the
    # data axes, KV heads over `model`, per-shard block-index tables);
    # only non-divisible axis extents keep the shard_map/jnp reference.
    backend = resolve_backend(cfg.backend, aqua=aqua)
    kernel_ok = (backend.decode is not None and aqua_on and not h2o
                 and cfg.window is None and aqua.block_dims > 1
                 and q.shape[-1] % aqua.block_dims == 0)
    kernel_mesh = None
    if kernel_ok and decode_mesh() is not None:
        from repro.distributed import sharding as dsh
        if dsh.kernel_shardable(decode_mesh(), cfg, aqua, batch=b):
            kernel_mesh = decode_mesh()
        else:
            _log_mesh_kernel_fallback(backend.name, "decode",
                                      REASON_NONDIVISIBLE_MESH)
            kernel_ok = False
    if kernel_ok:
        if kernel_mesh is not None:
            out = shard_mapped_decode_kernel(kernel_mesh, backend, q, cache,
                                             cfg=cfg, aqua=aqua)
        else:
            out = backend.decode(q, cache, cfg=cfg, aqua=aqua)
        out = jnp.einsum("bkgd,kgdm->bm", out, params["wo"].astype(x_t.dtype))
        return out, cache

    # masked-dense reference: materialize the per-query magnitude mask;
    # shard_map-wrapped (lanes × KV heads) when a serving mesh is installed
    qq = q * _aqua_mask(q, aqua, head_dim) if aqua_on else q
    mesh = decode_mesh()
    if mesh is not None:
        out, weights = _shard_mapped_decode_core(
            mesh, qq, cache.k, cache.v, cache.positions, cache.count,
            head_dim=head_dim, window=cfg.window)
    else:
        out, weights = _masked_dense_decode_core(
            qq, cache.k, cache.v, cache.positions, cache.count,
            head_dim=head_dim, window=cfg.window)
    if h2o:
        cache = kv.accumulate_h2o(cache, weights, write_mask=write_mask)
    out = jnp.einsum("bkgd,kgdm->bm", out, params["wo"].astype(x_t.dtype))
    return out, cache


def _paged_decode_product(params, x_t: jax.Array, q: jax.Array,
                          cache: kv.PagedAttnCache, cfg: AttentionConfig,
                          aqua: Optional[AquaConfig], *, h2o: bool,
                          write_mask: Optional[jax.Array]
                          ) -> Tuple[jax.Array, kv.PagedAttnCache]:
    """Read side of paged decode attention (the insert already ran).

    ``q`` is the projected (unmasked) query when AQUA is on. Dispatch
    mirrors the contiguous path exactly: the block-sparse Pallas kernel
    serves the full-cache policy (page table scalar-prefetched), running
    shard_mapped under a serving mesh (lane-partitioned page tables over
    the data axes, the lane-global pool KV-sharded over ``model``; see
    :func:`shard_mapped_paged_decode_kernel`) whenever
    ``distributed.sharding.kernel_shardable`` admits the geometry.
    Everything else — window rings, page-granular H2O, non-divisible
    extents, pages that don't tile the kernel's sequence blocks — runs
    the masked-dense reference on the gathered lane view, which is
    slot-for-slot identical to the contiguous cache layout.

    Hierarchical token sparsity (``use_token_sparsity`` installed by the
    engine) resolves the step's stage-1 participating-page table here,
    *before* any shard_map — the acc_pool is KV-sharded over ``model``
    under a mesh, so ranking must see the global pool (see
    :func:`shard_mapped_paged_decode_kernel`). The kernel path streams
    only participating pages; the reference path masks the same slots
    (positions < 0 are invalid in ``kv.valid_mask_from``), so both paths
    attend exactly the plan's token set.
    """
    aqua_on = aqua is not None and aqua.enabled
    head_dim = cfg.head_dim
    b = q.shape[0]
    backend = resolve_backend(cfg.backend, aqua=aqua)
    # stage-1 page participation: engages only where DispatchPlan's
    # token-sparsity predicate says so (no window, no H2O eviction —
    # REASON_TOKEN_*); a full keep (kept >= pages_per_lane) is a no-op.
    tok = token_sparsity()
    part_idx = None
    if (tok is not None and not h2o and cfg.window is None
            and tok[0] < cache.pages_per_lane):
        from repro.core import selection
        part_idx = selection.participating_pages(
            cache.acc_pool, cache.page_table, cache.count,
            page_size=cache.page_size, kept_pages=tok[0],
            pin_recent_pages=tok[1])
    kernel_ok = (backend.paged_decode is not None and aqua_on and not h2o
                 and cfg.window is None and aqua.block_dims > 1
                 and q.shape[-1] % aqua.block_dims == 0)
    if kernel_ok and cache.k_hot is not None:
        # mixed-precision hot residents only exist in the reference
        # path's dequantized lane view — the kernel reads raw int8 pages
        if decode_mesh() is not None:
            _log_mesh_kernel_fallback(backend.name, "decode",
                                      REASON_QUANT_RESIDENCY)
        kernel_ok = False
    kernel_mesh = None
    if kernel_ok and decode_mesh() is not None:
        from repro.distributed import sharding as dsh
        if dsh.kernel_shardable(decode_mesh(), cfg, aqua, batch=b,
                                page_size=cache.page_size):
            kernel_mesh = decode_mesh()
        else:
            reason = (REASON_PAGE_GEOMETRY
                      if cache.page_size % dsh.KERNEL_PAGE_MULTIPLE != 0
                      else REASON_NONDIVISIBLE_MESH)
            _log_mesh_kernel_fallback(backend.name, "decode", reason)
            kernel_ok = False
    if kernel_ok and cache.page_size % 8 != 0:
        # single-device: quietly keep the reference (same page-geometry
        # constraint kernel_shardable applies on the mesh path)
        kernel_ok = False
    if kernel_ok:
        if kernel_mesh is not None:
            out = shard_mapped_paged_decode_kernel(kernel_mesh, backend, q,
                                                   cache, cfg=cfg, aqua=aqua,
                                                   part_idx=part_idx)
        elif part_idx is not None:
            out = backend.paged_decode(q, cache, cfg=cfg, aqua=aqua,
                                       part_idx=part_idx)
        else:
            out = backend.paged_decode(q, cache, cfg=cfg, aqua=aqua)
        out = jnp.einsum("bkgd,kgdm->bm", out, params["wo"].astype(x_t.dtype))
        return out, cache

    qq = q * _aqua_mask(q, aqua, head_dim) if aqua_on else q
    view = kv.paged_lane_view(cache)
    positions = view.positions
    if part_idx is not None:
        # reference twin of the kernel's participation: non-participating
        # slots' positions drop to -1, which valid_mask_from masks off —
        # the reference attends exactly the kernel path's token set.
        from repro.core import selection
        slot_ok = selection.participation_slot_mask(
            part_idx, page_size=cache.page_size, num_slots=cache.num_slots)
        positions = jnp.where(slot_ok, positions, -1)
    mesh = decode_mesh()
    if mesh is not None:
        out, weights = _shard_mapped_decode_core(
            mesh, qq, view.k, view.v, positions, view.count,
            head_dim=head_dim, window=cfg.window)
    else:
        out, weights = _masked_dense_decode_core(
            qq, view.k, view.v, positions, view.count,
            head_dim=head_dim, window=cfg.window)
    if h2o:
        cache = kv.paged_accumulate_h2o(cache, weights,
                                        write_mask=write_mask)
    out = jnp.einsum("bkgd,kgdm->bm", out, params["wo"].astype(x_t.dtype))
    return out, cache
