"""Unified multi-head attention with first-class AQUA support.

Covers: MHA / GQA / MQA, full + sliding-window/local masks, RoPE, qk-norm,
QKV bias, AQUA projection + magnitude selection, AQUA-Memory static slice,
and H2O heavy-hitter eviction — for both prefill (sequence) and decode
(single-step with slot cache) modes. Pure jnp reference path; the Pallas
kernels in ``repro.kernels`` implement the bandwidth-optimal decode.

Conventions:
  x            (B, S, d_model)
  q            (B, S, KV, G, D)   G = group size (H = KV*G)
  k, v         (B, S, KV, D)
  proj P       (KV, D, D)         per-layer, per-GQA-group (paper §6.3)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from repro import runtime_flags as _rtf


def _scan(*args, **kw):
    kw.update(_rtf.scan_kwargs())
    return jax.lax.scan(*args, **kw)


from repro.configs.base import AquaConfig, AttentionConfig
from repro.core import aqua as aqua_lib
from repro.core import kvcache as kv

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding over the last axis. x: (..., S, ..., D) with
    positions broadcastable to x's sequence axis; here we require
    x: (B, S, *, D) and positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    # broadcast over head axes between S and D
    extra = x.ndim - 3  # number of axes between S and D
    for _ in range(extra):
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:2 * half]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    rest = x[..., 2 * half:]  # odd head dims (e.g. danube D=80 is even; safe)
    return jnp.concatenate([out1.astype(x.dtype), out2.astype(x.dtype), rest],
                           axis=-1)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Parameter init / QKV projection
# ---------------------------------------------------------------------------


def init_attention_params(rng: jax.Array, d_model: int, cfg: AttentionConfig,
                          dtype=jnp.float32) -> dict:
    h, g, d = cfg.num_kv_heads, cfg.group_size, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    std = d_model ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d_model, h, g, d), dtype) * std,
        "wk": jax.random.normal(k2, (d_model, h, d), dtype) * std,
        "wv": jax.random.normal(k3, (d_model, h, d), dtype) * std,
        "wo": jax.random.normal(k4, (h, g, d, d_model), dtype) * std,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, g, d), dtype)
        p["bk"] = jnp.zeros((h, d), dtype)
        p["bv"] = jnp.zeros((h, d), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((d,), dtype)
        p["k_norm"] = jnp.ones((d,), dtype)
    return p


def qkv(params: dict, x: jax.Array, cfg: AttentionConfig,
        positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns q (B,S,KV,G,D), k (B,S,KV,D), v (B,S,KV,D), RoPE'd."""
    q = jnp.einsum("bsm,mkgd->bskgd", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsm,mkd->bskd", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsm,mkd->bskd", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# AQUA projection helpers
# ---------------------------------------------------------------------------


def project_q(q: jax.Array, proj: Optional[jax.Array]) -> jax.Array:
    if proj is None:
        return q
    return jnp.einsum("bskgd,kde->bskge", q, proj.astype(q.dtype))


def project_k(k: jax.Array, proj: Optional[jax.Array]) -> jax.Array:
    if proj is None:
        return k
    return jnp.einsum("bskd,kde->bske", k, proj.astype(k.dtype))


def _aqua_prep(q, k, aqua: Optional[AquaConfig], proj, head_dim: int):
    """Project + statically slice q̂ and k̂ per AQUA config."""
    if aqua is None or not aqua.enabled:
        return q, k, None
    qh = project_q(q, proj)
    kh = project_k(k, proj)
    kept = aqua.kept_dims(head_dim)
    qh = qh[..., :kept]
    kh = kh[..., :kept]
    k_dims = aqua.topk_dims(head_dim)
    mask = aqua_lib.magnitude_mask(qh, k_dims, block_dims=aqua.block_dims)
    return qh, kh, mask


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — pure-XLA memory-efficient path used for
# long-sequence prefill; the S×S score matrix never materializes. On real
# TPU this role is played by kernels/flash_attention.py; the jnp version
# keeps the dry-run/compile path portable and GSPMD-shardable.
# ---------------------------------------------------------------------------

CHUNKED_THRESHOLD = 2048  # use chunked path for sequences >= this


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      head_dim: int, causal: bool = True,
                      window: Optional[int] = None, q_blk: int = 512,
                      k_blk: int = 1024) -> jax.Array:
    """q: (B, S, KV, G, D'); k: (B, S, KV, D'); v: (B, S, KV, Dv).

    Online-softmax double scan over (q blocks × k blocks). Scale uses the
    FULL head_dim (AQUA approximates full scores). Returns (B, S, KV, G, Dv).
    """
    b, s, kvh, g, d = q.shape
    dv = v.shape[-1]
    q_blk, k_blk = _rtf.attn_blocks(q_blk, k_blk)
    q_blk = min(q_blk, s)
    k_blk = min(k_blk, s)
    assert s % q_blk == 0 and s % k_blk == 0, (s, q_blk, k_blk)
    nq, nk = s // q_blk, s // k_blk
    scale = 1.0 / (float(head_dim) ** 0.5)

    qb = q.reshape(b, nq, q_blk, kvh, g, d).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(b, nk, k_blk, kvh, d).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, k_blk, kvh, dv).transpose(1, 0, 3, 2, 4)

    # Window-band restriction (§Perf iteration): for sliding-window
    # attention only the k-blocks intersecting the (window+q_blk) band
    # around the diagonal contribute; iterate exactly those (compute and
    # HBM bytes scale with the window, not the context). For full causal
    # attention iterate the causal prefix of k-blocks per q-block.
    band = None
    if causal and window is not None and window < s:
        band = min(nk, (q_blk + window) // k_blk + 2)

    def outer(_, qi_idx):
        qi, iq = qi_idx                     # (B,KV,G,qb,D), scalar

        def step(c, kj, vj, jk, valid):
            m, l, acc = c
            sij = jnp.einsum("bkgqd,bktd->bkgqt", qi.astype(jnp.float32),
                             kj.astype(jnp.float32)) * scale
            qpos = iq * q_blk + jnp.arange(q_blk)[:, None]
            kpos = jk * k_blk + jnp.arange(k_blk)[None, :]
            mask = jnp.ones((q_blk, k_blk), bool)
            if causal:
                mask &= qpos >= kpos
            if window is not None:
                mask &= kpos > qpos - window
            mask &= valid
            sij = jnp.where(mask[None, None, None], sij, NEG_INF)
            m_new = jnp.maximum(m, sij.max(-1))
            p = jnp.exp(sij - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqt,bktd->bkgqd", p, vj.astype(jnp.float32))
            return (m_new, l, acc)

        init = (jnp.full((b, kvh, g, q_blk), NEG_INF, jnp.float32),
                jnp.zeros((b, kvh, g, q_blk), jnp.float32),
                jnp.zeros((b, kvh, g, q_blk, dv), jnp.float32))

        if band is not None:
            last = ((iq + 1) * q_blk - 1) // k_blk  # last needed k-block

            def inner_band(c, j):
                raw = last - (band - 1) + j         # may be < 0 early on
                idx = jnp.clip(raw, 0, nk - 1)
                kj = jax.lax.dynamic_index_in_dim(kb, idx, 0, False)
                vj = jax.lax.dynamic_index_in_dim(vb, idx, 0, False)
                return step(c, kj, vj, idx, raw >= 0), None
            (m, l, acc), _ = _scan(inner_band, init,
                                   jnp.arange(band))
        else:
            def inner(c, kj_idx):
                kj, vj, jk = kj_idx
                return step(c, kj, vj, jk, True), None
            (m, l, acc), _ = _scan(
                inner, init, (kb, vb, jnp.arange(nk)))
        return None, acc / jnp.maximum(l, 1e-30)[..., None]

    _, ob = _scan(outer, None, (qb, jnp.arange(nq)))
    # (nq, B, KV, G, q_blk, Dv) -> (B, S, KV, G, Dv)
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, kvh, g, dv)
    return out


# ---------------------------------------------------------------------------
# Prefill attention (full sequence)
# ---------------------------------------------------------------------------


def prefill_attention(params: dict, x: jax.Array, cfg: AttentionConfig,
                      aqua: Optional[AquaConfig] = None,
                      proj: Optional[jax.Array] = None,
                      positions: Optional[jax.Array] = None,
                      kv_x: Optional[jax.Array] = None,
                      return_aux: bool = False):
    """Sequence attention. ``kv_x`` enables cross-attention (keys/values from
    the encoder); in that mode AQUA and causal masking are bypassed unless
    configured otherwise.

    Returns out (B, S, d_model) [, aux dict with q/k activations & weights].
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    src = x if kv_x is None else kv_x

    q = jnp.einsum("bsm,mkgd->bskgd", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsm,mkd->bskd", src, params["wk"].astype(src.dtype))
    v = jnp.einsum("bsm,mkd->bskd", src, params["wv"].astype(src.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if cfg.use_rope and kv_x is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    qh, kh, mask = _aqua_prep(q, k, aqua, proj, cfg.head_dim)
    qq, kk = (q, k) if mask is None else (qh * mask, kh)

    if (s >= CHUNKED_THRESHOLD and kv_x is None and cfg.causal
            and positions.ndim == 1):
        out = chunked_attention(qq, kk, v, head_dim=cfg.head_dim,
                                causal=True, window=cfg.window)
        out = out.astype(v.dtype)
        out = jnp.einsum("bskgd,kgdm->bsm", out, params["wo"].astype(x.dtype))
        if return_aux:
            return out, {"q": q, "k": k, "weights": None,
                         "q_hat": qh if mask is not None else None,
                         "k_hat": kh if mask is not None else None}
        return out

    scores = jnp.einsum("bskgd,btkd->bkgst", qq, kk)
    scores = scores.astype(jnp.float32) / jnp.sqrt(float(cfg.head_dim))

    if cfg.causal and kv_x is None:
        qpos = positions if positions.ndim == 2 else positions[None]
        kpos = qpos
        causal = qpos[:, None, None, :, None] >= kpos[:, None, None, None, :]
        if cfg.window is not None:
            causal &= (kpos[:, None, None, None, :]
                       > qpos[:, None, None, :, None] - cfg.window)
        scores = jnp.where(causal, scores, NEG_INF)
    weights = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", weights.astype(v.dtype), v)
    out = jnp.einsum("bskgd,kgdm->bsm", out, params["wo"].astype(x.dtype))
    if return_aux:
        aux = {"q": q, "k": k, "weights": weights,
               "q_hat": qh if mask is not None else None,
               "k_hat": kh if mask is not None else None}
        return out, aux
    return out


# ---------------------------------------------------------------------------
# Prefill -> cache handoff
# ---------------------------------------------------------------------------


def build_cache_from_prefill(params: dict, x: jax.Array, cfg: AttentionConfig,
                             aqua: Optional[AquaConfig],
                             proj: Optional[jax.Array],
                             max_seq: int) -> kv.AttnCache:
    """Construct the decode cache after a prefill pass (serving engine)."""
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    q, k, v = qkv(params, x, cfg, positions)
    head_dim = cfg.head_dim
    if aqua is not None and aqua.enabled:
        k = project_k(k, proj)[..., :aqua.kept_dims(head_dim)]
    dk, dv = k.shape[-1], v.shape[-1]

    h2o_budget = None
    if aqua is not None and aqua.h2o_ratio < 1.0:
        h2o_budget = max(8, int(aqua.h2o_ratio * max_seq))
    slots = kv.cache_slots(max_seq, cfg.window, h2o_budget)
    cache = kv.init_attn_cache(b, cfg.num_kv_heads, slots, dk, dv, k.dtype)

    if h2o_budget is not None and s > slots:
        # H2O prefill: accumulated (approximate, if AQUA) attention mass.
        # NB: k above is already projected + sliced when AQUA is on, so we
        # only transform the query side here.
        qq = q
        if aqua.enabled and proj is not None:
            qq = project_q(q, proj)[..., :aqua.kept_dims(head_dim)]
            m = aqua_lib.magnitude_mask(qq, aqua.topk_dims(head_dim),
                                        block_dims=aqua.block_dims)
            qq = qq * m
        sc = jnp.einsum("bskgd,btkd->bkgst", qq, k)
        sc = sc.astype(jnp.float32) / jnp.sqrt(float(head_dim))
        causal = positions[:, None] >= positions[None, :]
        sc = jnp.where(causal[None, None, None], sc, NEG_INF)
        w = jax.nn.softmax(sc, axis=-1)
        acc = w.sum(axis=(2, 3))  # (B, KV, S) summed over groups & queries
        recent = max(1, int(aqua.h2o_recent_frac * slots))
        keep_hh = slots - recent
        score_tok = acc.sum(axis=1)  # (B, S)
        # protect the recent window from scored selection
        score_tok = score_tok.at[:, s - recent:].set(-jnp.inf)
        _, hh_idx = jax.lax.top_k(score_tok, keep_hh)
        recent_idx = jnp.broadcast_to(jnp.arange(s - recent, s), (b, recent))
        sel = jnp.concatenate([jnp.sort(hh_idx, axis=-1), recent_idx], axis=-1)
        # gather selected tokens: (S, KV, D)[sel] -> (slots, KV, D) -> (KV, slots, D)
        take = jax.vmap(lambda a, i: a[i].transpose(1, 0, 2), in_axes=(0, 0))
        cache = kv.AttnCache(
            k=take(k, sel), v=take(v, sel),
            positions=jnp.take_along_axis(
                jnp.broadcast_to(positions, (b, s)), sel, axis=-1),
            count=jnp.full((b,), s, jnp.int32),
            acc_score=jnp.take_along_axis(acc, sel[:, None, :], axis=-1),
        )
        return cache

    # full / window caches: last `slots` tokens, ring-consistent placement.
    start = max(0, s - slots)
    ring = cfg.window is not None
    tok_pos = positions[start:]
    slot_idx = (tok_pos % slots) if ring else (tok_pos - start)
    cache = kv.AttnCache(
        k=cache.k.at[:, :, slot_idx].set(k[:, start:].transpose(0, 2, 1, 3)),
        v=cache.v.at[:, :, slot_idx].set(v[:, start:].transpose(0, 2, 1, 3)),
        positions=cache.positions.at[:, slot_idx].set(tok_pos[None]),
        count=jnp.full((b,), s, jnp.int32),
        acc_score=cache.acc_score,
    )
    return cache


# ---------------------------------------------------------------------------
# Decode attention (single step, slot cache)
# ---------------------------------------------------------------------------


def decode_attention(params: dict, x_t: jax.Array, cache: kv.AttnCache,
                     cfg: AttentionConfig, aqua: Optional[AquaConfig] = None,
                     proj: Optional[jax.Array] = None,
                     cross: Optional[Tuple[jax.Array, jax.Array]] = None,
                     ) -> Tuple[jax.Array, kv.AttnCache]:
    """One decode step. x_t: (B, d_model). Returns (out (B, d_model), cache).

    ``cross`` = (k_enc, v_enc) each (B, S_enc, KV, D) for cross-attention
    layers (whisper decoder); those bypass the cache entirely.
    """
    b = x_t.shape[0]
    if cross is not None:
        k_enc, v_enc = cross
        q = jnp.einsum("bm,mkgd->bkgd", x_t, params["wq"].astype(x_t.dtype))
        if cfg.qkv_bias:
            q = q + params["bq"].astype(x_t.dtype)
        if cfg.qk_norm:
            q = rms_norm(q, params["q_norm"])
        sc = jnp.einsum("bkgd,bskd->bkgs", q, k_enc).astype(jnp.float32)
        w = jax.nn.softmax(sc / jnp.sqrt(float(cfg.head_dim)), axis=-1)
        out = jnp.einsum("bkgs,bskd->bkgd", w.astype(v_enc.dtype), v_enc)
        out = jnp.einsum("bkgd,kgdm->bm", out, params["wo"].astype(x_t.dtype))
        return out, cache

    pos = cache.count  # (B,) position of the incoming token
    q, k, v = qkv(params, x_t[:, None, :], cfg, pos[:, None])
    q, k_t, v_t = q[:, 0], k[:, 0], v[:, 0]  # (B,KV,G,D), (B,KV,D)

    head_dim = cfg.head_dim
    mask = None
    if aqua is not None and aqua.enabled:
        qh = jnp.einsum("bkgd,kde->bkge", q, proj.astype(q.dtype))
        kh = jnp.einsum("bkd,kde->bke", k_t, proj.astype(k_t.dtype))
        kept = aqua.kept_dims(head_dim)
        q, k_t = qh[..., :kept], kh[..., :kept]
        mask = aqua_lib.magnitude_mask(q, aqua.topk_dims(head_dim),
                                       block_dims=aqua.block_dims)

    h2o = aqua is not None and aqua.enabled and aqua.h2o_ratio < 1.0
    recent_len = 0
    if h2o:
        recent_len = max(1, int(aqua.h2o_recent_frac * cache.num_slots))
    slot = kv.select_slot(cache, window=cfg.window, h2o=h2o,
                          recent_len=recent_len)
    cache = kv.insert(cache, slot, k_t, v_t)

    qq = q if mask is None else q * mask
    scores = jnp.einsum("bkgd,bksd->bkgs", qq, cache.k.astype(qq.dtype))
    scores = scores.astype(jnp.float32) / jnp.sqrt(float(head_dim))
    vm = kv.valid_mask(cache, window=cfg.window)  # (B, S_slots)
    scores = jnp.where(vm[:, None, None, :], scores, NEG_INF)
    weights = jax.nn.softmax(scores, axis=-1)
    if h2o:
        cache = kv.accumulate_h2o(cache, weights)
    out = jnp.einsum("bkgs,bksd->bkgd", weights.astype(cache.v.dtype), cache.v)
    out = jnp.einsum("bkgd,kgdm->bm", out, params["wo"].astype(x_t.dtype))
    return out, cache
