"""Decode-time caches.

One unified slot-based cache covers every policy in the framework:

  * standard full cache        (slots = max_seq, slot s holds position s)
  * sliding / local window     (slots = window, ring buffer)
  * H2O heavy-hitter budget    (slots = budget, victim = argmin acc score)
  * AQUA projected cache       (keys stored projected, dim-major [D, S],
                                optionally statically sliced — AQUA-Memory)

Slots carry an explicit ``positions`` array so masking, RoPE and recency
protection are uniform across policies. Everything is static-shaped and
jit/pjit friendly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclass
class AttnCache:
    """Per-layer attention cache.

    k: (B, KV, S_slots, Dk)  — keys; *projected and sliced* when AQUA is on.
       Stored seq-major here; the Pallas decode kernel consumes the
       dim-major transpose view (see kernels/aqua_decode.py).
    v: (B, KV, S_slots, Dv)
    positions: (B, S_slots) int32 — token position held by each slot, -1 empty.
    count: (B,) int32 — number of tokens processed so far (= next position).
    acc_score: (B, KV, S_slots) f32 — H2O accumulated attention mass
       (zeros when H2O disabled; kept unconditionally for pytree stability).
    """

    k: jax.Array
    v: jax.Array
    positions: jax.Array
    count: jax.Array
    acc_score: jax.Array

    @property
    def num_slots(self) -> int:
        return self.k.shape[2]


def init_attn_cache(batch: int, num_kv: int, slots: int, dk: int, dv: int,
                    dtype=jnp.bfloat16) -> AttnCache:
    return AttnCache(
        k=jnp.zeros((batch, num_kv, slots, dk), dtype),
        v=jnp.zeros((batch, num_kv, slots, dv), dtype),
        positions=jnp.full((batch, slots), -1, jnp.int32),
        count=jnp.zeros((batch,), jnp.int32),
        acc_score=jnp.zeros((batch, num_kv, slots), jnp.float32),
    )


def cache_slots(max_seq: int, window: Optional[int], h2o_budget: Optional[int]
                ) -> int:
    s = max_seq
    if window is not None:
        s = min(s, window)
    if h2o_budget is not None:
        s = min(s, h2o_budget)
    return max(s, 1)


def select_slot(cache: AttnCache, *, window: Optional[int],
                h2o: bool, recent_len: int) -> jax.Array:
    """Slot index (B,) where the incoming token's K/V should be written.

    Policies: ring buffer (window only), contiguous (full cache), H2O
    heavy-hitter eviction, and the combined window+H2O policy: slots whose
    position has slid out of the attention window are dead weight (the
    valid mask will never admit them again), so they are evicted *first*;
    only when every held slot is still in-window does the accumulated-score
    victim selection kick in.
    """
    b, _, s_slots, _ = cache.k.shape
    count = cache.count  # (B,)
    if window is not None and not h2o:
        # ring buffer
        return count % s_slots
    if not h2o:
        return jnp.minimum(count, s_slots - 1)
    # H2O: free slot while not full, else evict argmin-acc among non-recent.
    cur = count  # position of incoming token
    protected = cache.positions > (cur[:, None] - recent_len)  # (B, S)
    protected |= cache.positions < 0  # can't "evict" empties via score path
    score = cache.acc_score.sum(axis=1)  # (B, S) summed over kv heads
    score = jnp.where(protected, jnp.inf, score)
    if window is not None:
        # combined H2O+window: prefer evicting slots that fell out of the
        # window — they can never be attended again regardless of score.
        stale = (cache.positions >= 0) & \
            (cache.positions <= cur[:, None] - window)
        score = jnp.where(stale & ~protected, -jnp.inf, score)
    victim = jnp.argmin(score, axis=-1).astype(jnp.int32)
    free = jnp.minimum(count, s_slots - 1)
    return jnp.where(count < s_slots, free, victim)


def insert(cache: AttnCache, slot: jax.Array, k_new: jax.Array,
           v_new: jax.Array,
           write_mask: Optional[jax.Array] = None) -> AttnCache:
    """Write one token's (projected/sliced) k, v into ``slot``.

    k_new: (B, KV, Dk); v_new: (B, KV, Dv); slot: (B,).

    ``write_mask`` (B,) bool suppresses the write for masked-off rows:
    their k/v/positions/count are left untouched. The continuous-batching
    engine uses this to freeze inactive lanes while the shared decode step
    runs at static batch shape.
    """
    b = jnp.arange(cache.k.shape[0])
    k = cache.k.at[b, :, slot].set(k_new.astype(cache.k.dtype))
    v = cache.v.at[b, :, slot].set(v_new.astype(cache.v.dtype))
    positions = cache.positions.at[b, slot].set(cache.count)
    acc = cache.acc_score.at[b, :, slot].set(0.0)
    count = cache.count + 1
    if write_mask is not None:
        m = write_mask
        k = jnp.where(m[:, None, None, None], k, cache.k)
        v = jnp.where(m[:, None, None, None], v, cache.v)
        positions = jnp.where(m[:, None], positions, cache.positions)
        acc = jnp.where(m[:, None, None], acc, cache.acc_score)
        count = jnp.where(m, count, cache.count)
    return AttnCache(k=k, v=v, positions=positions, count=count,
                     acc_score=acc)


def valid_mask(cache: AttnCache, *, window: Optional[int]) -> jax.Array:
    """(B, S_slots) bool — slots attendable by the current token."""
    return valid_mask_from(cache.positions, cache.count, window=window)


def valid_mask_from(positions: jax.Array, count: jax.Array, *,
                    window: Optional[int]) -> jax.Array:
    """``valid_mask`` on bare arrays — the shard_map decode path calls
    this on per-shard cache leaves rather than a full AttnCache."""
    cur = count[:, None] - 1  # position of the token now attending
    m = (positions >= 0) & (positions <= cur)
    if window is not None:
        m &= positions > (cur - window)
    return m


def accumulate_h2o(cache: AttnCache, attn_weights: jax.Array,
                   write_mask: Optional[jax.Array] = None) -> AttnCache:
    """attn_weights: (B, KV, G, S_slots) probabilities for the current step;
    summed over the G query heads of each kv group (H2O statistic).
    ``write_mask`` (B,) freezes masked-off rows (inactive lanes)."""
    upd = attn_weights.astype(jnp.float32).sum(axis=2)
    if write_mask is not None:
        upd = jnp.where(write_mask[:, None, None], upd, 0.0)
    return dataclasses.replace(cache, acc_score=cache.acc_score + upd)


# ---------------------------------------------------------------------------
# SSM / recurrent caches
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclass
class SSMCache:
    """Mamba-2 per-layer state: rolling conv window + SSD state."""

    conv: jax.Array   # (B, conv_width-1, conv_channels)
    state: jax.Array  # (B, nheads, head_dim, state_dim)
    count: jax.Array  # (B,)


@jax.tree_util.register_dataclass
@dataclass
class RGLRUCache:
    """RecurrentGemma recurrent-block state."""

    conv: jax.Array   # (B, conv_width-1, lru_width)
    state: jax.Array  # (B, lru_width) real-gated LRU hidden state
    count: jax.Array
