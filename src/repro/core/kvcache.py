"""Decode-time caches.

One unified slot-based cache covers every policy in the framework:

  * standard full cache        (slots = max_seq, slot s holds position s)
  * sliding / local window     (slots = window, ring buffer)
  * H2O heavy-hitter budget    (slots = budget, victim = argmin acc score)
  * AQUA projected cache       (keys stored projected, dim-major [D, S],
                                optionally statically sliced — AQUA-Memory)

Slots carry an explicit ``positions`` array so masking, RoPE and recency
protection are uniform across policies. Everything is static-shaped and
jit/pjit friendly.

Block-paged variant (:class:`PagedAttnCache`): the same *logical* slot
space per lane, but physical storage lives in a global page pool shared
by all lanes — per-lane page tables map logical page ``slot // page_size``
to a physical pool page. HBM footprint scales with the pool size (actual
occupancy) instead of ``lanes × max_seq``, read-only pages can be mapped
into several lanes at once (prefix sharing, refcounted host-side by
``repro.serving.scheduler.PagePool``), and H2O eviction turns
page-granular: the accumulated-score victim frees a *whole page*. Because
the logical slot space is unchanged, the full-cache and sliding-window
policies are slot-for-slot identical to the contiguous cache (paged
decode is token-identical at greedy); only the H2O policy deliberately
diverges to whole-page victims. All paged operations are static-shaped
and jit-safe: the host allocator only ever writes page-table rows between
steps.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclass
class AttnCache:
    """Per-layer attention cache.

    k: (B, KV, S_slots, Dk)  — keys; *projected and sliced* when AQUA is on.
       Stored seq-major here; the Pallas decode kernel consumes the
       dim-major transpose view (see kernels/aqua_decode.py).
    v: (B, KV, S_slots, Dv)
    positions: (B, S_slots) int32 — token position held by each slot, -1 empty.
    count: (B,) int32 — number of tokens processed so far (= next position).
    acc_score: (B, KV, S_slots) f32 — H2O accumulated attention mass
       (zeros when H2O disabled; kept unconditionally for pytree stability).
    """

    k: jax.Array
    v: jax.Array
    positions: jax.Array
    count: jax.Array
    acc_score: jax.Array

    @property
    def num_slots(self) -> int:
        return self.k.shape[2]


def init_attn_cache(batch: int, num_kv: int, slots: int, dk: int, dv: int,
                    dtype=jnp.bfloat16) -> AttnCache:
    return AttnCache(
        k=jnp.zeros((batch, num_kv, slots, dk), dtype),
        v=jnp.zeros((batch, num_kv, slots, dv), dtype),
        positions=jnp.full((batch, slots), -1, jnp.int32),
        count=jnp.zeros((batch,), jnp.int32),
        acc_score=jnp.zeros((batch, num_kv, slots), jnp.float32),
    )


def cache_slots(max_seq: int, window: Optional[int], h2o_budget: Optional[int]
                ) -> int:
    s = max_seq
    if window is not None:
        s = min(s, window)
    if h2o_budget is not None:
        s = min(s, h2o_budget)
    return max(s, 1)


def select_slot(cache: AttnCache, *, window: Optional[int],
                h2o: bool, recent_len: int) -> jax.Array:
    """Slot index (B,) where the incoming token's K/V should be written.

    Policies: ring buffer (window only), contiguous (full cache), H2O
    heavy-hitter eviction, and the combined window+H2O policy: slots whose
    position has slid out of the attention window are dead weight (the
    valid mask will never admit them again), so they are evicted *first*;
    only when every held slot is still in-window does the accumulated-score
    victim selection kick in.
    """
    b, _, s_slots, _ = cache.k.shape
    count = cache.count  # (B,)
    if window is not None and not h2o:
        # ring buffer
        return count % s_slots
    if not h2o:
        return jnp.minimum(count, s_slots - 1)
    # H2O: free slot while not full, else evict argmin-acc among non-recent.
    cur = count  # position of incoming token
    protected = cache.positions > (cur[:, None] - recent_len)  # (B, S)
    protected |= cache.positions < 0  # can't "evict" empties via score path
    score = cache.acc_score.sum(axis=1)  # (B, S) summed over kv heads
    score = jnp.where(protected, jnp.inf, score)
    if window is not None:
        # combined H2O+window: prefer evicting slots that fell out of the
        # window — they can never be attended again regardless of score.
        stale = (cache.positions >= 0) & \
            (cache.positions <= cur[:, None] - window)
        score = jnp.where(stale & ~protected, -jnp.inf, score)
    victim = jnp.argmin(score, axis=-1).astype(jnp.int32)
    free = jnp.minimum(count, s_slots - 1)
    return jnp.where(count < s_slots, free, victim)


def insert(cache: AttnCache, slot: jax.Array, k_new: jax.Array,
           v_new: jax.Array,
           write_mask: Optional[jax.Array] = None) -> AttnCache:
    """Write one token's (projected/sliced) k, v into ``slot``.

    k_new: (B, KV, Dk); v_new: (B, KV, Dv); slot: (B,).

    ``write_mask`` (B,) bool suppresses the write for masked-off rows:
    their k/v/positions/count are left untouched. The continuous-batching
    engine uses this to freeze inactive lanes while the shared decode step
    runs at static batch shape.
    """
    b = jnp.arange(cache.k.shape[0])
    k = cache.k.at[b, :, slot].set(k_new.astype(cache.k.dtype))
    v = cache.v.at[b, :, slot].set(v_new.astype(cache.v.dtype))
    positions = cache.positions.at[b, slot].set(cache.count)
    acc = cache.acc_score.at[b, :, slot].set(0.0)
    count = cache.count + 1
    if write_mask is not None:
        m = write_mask
        k = jnp.where(m[:, None, None, None], k, cache.k)
        v = jnp.where(m[:, None, None, None], v, cache.v)
        positions = jnp.where(m[:, None], positions, cache.positions)
        acc = jnp.where(m[:, None, None], acc, cache.acc_score)
        count = jnp.where(m, count, cache.count)
    return AttnCache(k=k, v=v, positions=positions, count=count,
                     acc_score=acc)


def lane_write_tail(cache: AttnCache, lane: jax.Array, k_tail: jax.Array,
                    v_tail: jax.Array, positions: jax.Array,
                    start: jax.Array, new_count: jax.Array) -> AttnCache:
    """Write a prefill *chunk*'s K/V into one lane of a contiguous
    full-cache, leaving slots below ``start`` untouched.

    The contiguous counterpart of :func:`paged_write_tail`: k_tail
    (T, KV, Dk) / v_tail (T, KV, Dv) / positions (T,) start at logical
    slot ``start`` (the chunk cursor). Slots at/beyond ``start`` are
    cleared first (positions -1, scores 0) so a recycled lane's previous
    tenant never reads as valid — the first chunk (``start`` 0) therefore
    wipes the whole lane, later chunks only clear ahead of themselves.
    Full-cache slot placement only (slot i holds position i); window
    rings and H2O eviction place slots differently and must keep
    monolithic admission.
    """
    s = cache.num_slots
    t = k_tail.shape[0]
    ahead = jnp.arange(s) >= start                       # (S,)
    pos_row = jnp.where(ahead, -1, cache.positions[lane])
    acc_row = jnp.where(ahead[None, :], 0.0, cache.acc_score[lane])
    idx = start + jnp.arange(t)
    k = cache.k.at[lane, :, idx].set(k_tail.astype(cache.k.dtype),
                                     mode="drop")
    v = cache.v.at[lane, :, idx].set(v_tail.astype(cache.v.dtype),
                                     mode="drop")
    pos_row = pos_row.at[idx].set(positions, mode="drop")
    acc_row = acc_row.at[:, idx].set(0.0, mode="drop")
    return dataclasses.replace(
        cache, k=k, v=v,
        positions=cache.positions.at[lane].set(pos_row),
        acc_score=cache.acc_score.at[lane].set(acc_row),
        count=cache.count.at[lane].set(new_count))


def valid_mask(cache: AttnCache, *, window: Optional[int]) -> jax.Array:
    """(B, S_slots) bool — slots attendable by the current token."""
    return valid_mask_from(cache.positions, cache.count, window=window)


def valid_mask_from(positions: jax.Array, count: jax.Array, *,
                    window: Optional[int]) -> jax.Array:
    """``valid_mask`` on bare arrays — the shard_map decode path calls
    this on per-shard cache leaves rather than a full AttnCache."""
    cur = count[:, None] - 1  # position of the token now attending
    m = (positions >= 0) & (positions <= cur)
    if window is not None:
        m &= positions > (cur - window)
    return m


def accumulate_h2o(cache: AttnCache, attn_weights: jax.Array,
                   write_mask: Optional[jax.Array] = None) -> AttnCache:
    """attn_weights: (B, KV, G, S_slots) probabilities for the current step;
    summed over the G query heads of each kv group (H2O statistic).
    ``write_mask`` (B,) freezes masked-off rows (inactive lanes)."""
    upd = attn_weights.astype(jnp.float32).sum(axis=2)
    if write_mask is not None:
        upd = jnp.where(write_mask[:, None, None], upd, 0.0)
    return dataclasses.replace(cache, acc_score=cache.acc_score + upd)


# ---------------------------------------------------------------------------
# Block-paged cache: global page pool + per-lane page tables
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclass
class PagedAttnCache:
    """Per-layer paged attention cache.

    k_pool: (P, KV, page_size, Dk) — global key page pool (projected and
       sliced when AQUA is on; the paged Pallas decode kernel consumes the
       dim-major transpose view per page, see kernels/aqua_decode.py).
    v_pool: (P, KV, page_size, Dv)
    pos_pool: (P, page_size) int32 — token position held by each pool
       slot, -1 empty. Stored per *physical* page: positions of a shared
       (read-only, refcounted) page are identical in every lane that maps
       it, so per-lane copies would be redundant.
    acc_pool: (P, KV, page_size) f32 — H2O accumulated attention mass.
    page_table: (B, pages_per_lane) int32 — physical page backing each
       logical page of the lane, -1 unmapped. Logical slot ``s`` of a lane
       lives at ``(page_table[b, s // page_size], s % page_size)``.
    count: (B,) int32 — tokens processed so far (= next position).

    Quantized pools (``QuantSpec.kv_dtype="int8"``): ``k_pool``/``v_pool``
    hold per-page symmetric-quantized int8 values and the optional scale
    leaves become live —

    k_scale / v_scale: (P, SH) f32 per-page scales beside the page table
       (``real = int * scale``; zero-point 0, scale 0 = unwritten page).
       SH is the scale granularity encoded in the shape: ``num_kv`` for
       per-(page, kv-head) scales, 1 for one shared scale per page.
    k_hot / v_hot: (H, KV, page_size, D) full-precision *hot-resident*
       overlay (mixed precision): the int8 pool stays authoritative and
       always written, residents additionally carry an exact write-through
       copy that readers prefer. ``hot_ids``: (H,) int32 physical page id
       of each resident, -1 empty. Residency follows the H2O accumulated
       scores: grafts promote the freshest page, evicting the
       lowest-score resident; freed/recycled pages are demoted.

    The logical slot space (``pages_per_lane * page_size`` slots) matches
    the contiguous :class:`AttnCache` layout exactly, so every policy's
    slot arithmetic carries over through the indirection.
    """

    k_pool: jax.Array
    v_pool: jax.Array
    pos_pool: jax.Array
    acc_pool: jax.Array
    page_table: jax.Array
    count: jax.Array
    k_scale: Optional[jax.Array] = None
    v_scale: Optional[jax.Array] = None
    k_hot: Optional[jax.Array] = None
    v_hot: Optional[jax.Array] = None
    hot_ids: Optional[jax.Array] = None

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @property
    def num_pages(self) -> int:
        return self.k_pool.shape[0]

    @property
    def page_size(self) -> int:
        return self.k_pool.shape[2]

    @property
    def pages_per_lane(self) -> int:
        return self.page_table.shape[1]

    @property
    def num_slots(self) -> int:
        """Logical slots per lane (= contiguous cache's slot count)."""
        return self.pages_per_lane * self.page_size


def paged_pages(slots: int, page_size: int) -> int:
    """Pages per lane for a logical capacity of ``slots``. The logical
    slot space must tile into whole pages so the ring / eviction slot
    arithmetic is identical to the contiguous cache — callers validate
    ``slots % page_size == 0`` (ServingConfig does for serving)."""
    assert slots % page_size == 0, \
        f"cache slots {slots} must be a multiple of page_size {page_size}"
    return slots // page_size


#: int8 symmetric quantization range (zero-point is always 0).
QUANT_MAX = 127.0


def init_paged_cache(batch: int, num_kv: int, num_pages: int,
                     pages_per_lane: int, page_size: int, dk: int, dv: int,
                     dtype=jnp.bfloat16, kv_dtype: Optional[str] = None,
                     scale_granularity: str = "page_head",
                     hot_pages: int = 0) -> PagedAttnCache:
    """``kv_dtype`` None/"bf16" keeps full-precision pools; "int8" stores
    per-page symmetric-quantized pools with f32 scale metadata (see
    :class:`PagedAttnCache`). ``scale_granularity`` picks the scale shape
    ("page_head" → one scale per (page, kv head), "page" → one per page)
    and ``hot_pages`` > 0 allocates the mixed-precision hot-resident
    overlay."""
    quant = kv_dtype not in (None, "bf16")
    if quant and kv_dtype != "int8":
        raise ValueError(f"unsupported kv_dtype {kv_dtype!r}")
    pool_dtype = jnp.int8 if quant else dtype
    extra = {}
    if quant:
        sh = num_kv if scale_granularity == "page_head" else 1
        extra = dict(
            k_scale=jnp.zeros((num_pages, sh), jnp.float32),
            v_scale=jnp.zeros((num_pages, sh), jnp.float32))
        if hot_pages > 0:
            extra.update(
                k_hot=jnp.zeros((hot_pages, num_kv, page_size, dk), dtype),
                v_hot=jnp.zeros((hot_pages, num_kv, page_size, dv), dtype),
                hot_ids=jnp.full((hot_pages,), -1, jnp.int32))
    return PagedAttnCache(
        k_pool=jnp.zeros((num_pages, num_kv, page_size, dk), pool_dtype),
        v_pool=jnp.zeros((num_pages, num_kv, page_size, dv), pool_dtype),
        pos_pool=jnp.full((num_pages, page_size), -1, jnp.int32),
        acc_pool=jnp.zeros((num_pages, num_kv, page_size), jnp.float32),
        page_table=jnp.full((batch, pages_per_lane), -1, jnp.int32),
        count=jnp.zeros((batch,), jnp.int32),
        **extra,
    )


def dequant_pages(pool: jax.Array, scale: jax.Array,
                  dtype=jnp.float32) -> jax.Array:
    """int8 pages (..., KV, ps, D) × per-page scales (..., SH) -> dtype.
    SH broadcasts over KV when the granularity is one-scale-per-page."""
    return (pool.astype(jnp.float32)
            * scale[..., :, None, None]).astype(dtype)


def quantize_tokens(x: jax.Array, scale: jax.Array) -> jax.Array:
    """float tokens (..., D) / scales broadcastable to ``x[..., 0]`` ->
    int8. Zero scale (unwritten page / all-zero content) quantizes to 0
    instead of dividing by zero."""
    s = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.round(x.astype(jnp.float32) / s[..., None])
    return jnp.clip(q, -QUANT_MAX, QUANT_MAX).astype(jnp.int8)


def _page_scales(tok: jax.Array, ps: int, sh: int) -> jax.Array:
    """Per-page scales for (T, KV, D) float tokens laid out from a page
    boundary -> (ceil(T/ps), SH); the partial last page pads with zeros
    (which never grow the amax)."""
    t, kvh, d = tok.shape
    npg = -(-t // ps)
    x = jnp.abs(tok.astype(jnp.float32))
    x = jnp.pad(x, ((0, npg * ps - t), (0, 0), (0, 0)))
    amax = x.reshape(npg, ps, kvh, d).max(axis=(1, 3))   # (NPG, KV)
    if sh == 1:
        amax = amax.max(axis=-1, keepdims=True)
    return amax / QUANT_MAX


def _insert_quant_token(pool: jax.Array, scale: jax.Array, phys: jax.Array,
                        off: jax.Array, x_new: jax.Array
                        ) -> Tuple[jax.Array, jax.Array]:
    """Quantized single-token insert with a per-page *running* scale:
    grow the page's scale to cover the new token's amax (requantizing the
    already-stored page ints when it grows — round-trip error stays one
    rounding step per growth) and write the quantized token. ``phys``
    (B,) already encodes suppressed rows as the out-of-bounds page."""
    x = x_new.astype(jnp.float32)                        # (B, KV, D)
    amax = jnp.abs(x).max(axis=-1)                       # (B, KV)
    if scale.shape[1] == 1:
        amax = amax.max(axis=-1, keepdims=True)          # (B, 1)
    safe = jnp.minimum(phys, pool.shape[0] - 1)
    s_old = scale[safe]                                  # (B, SH)
    s_cand = jnp.maximum(s_old, amax / QUANT_MAX)
    ratio = jnp.where(s_cand > 0.0, s_old / s_cand, 1.0)
    page = pool[safe].astype(jnp.float32)                # (B, KV, ps, D)
    requant = jnp.clip(jnp.round(page * ratio[:, :, None, None]),
                       -QUANT_MAX, QUANT_MAX).astype(pool.dtype)
    pool = pool.at[phys].set(requant, mode="drop")
    pool = pool.at[phys, :, off].set(quantize_tokens(x, s_cand), mode="drop")
    scale = scale.at[phys].set(s_cand, mode="drop")
    return pool, scale


def _demote_residents(hot_ids: jax.Array, freed_phys: jax.Array) -> jax.Array:
    """Drop hot residents whose physical page appears in ``freed_phys``
    (1-D, out-of-bounds entries never match): recycled pages must not
    serve a stale full-precision overlay."""
    stale = (hot_ids[:, None] == freed_phys[None, :]).any(axis=1)
    return jnp.where(stale, -1, hot_ids)


def _hot_overlay(vals: jax.Array, hot_pool: jax.Array, table: jax.Array,
                 hot_ids: jax.Array) -> jax.Array:
    """Overlay resident pages onto dequantized gathers: vals (B, NP, KV,
    ps, D) with page table (B, NP); resident pages (table entry matching a
    live ``hot_ids`` slot) read the exact ``hot_pool`` copy instead."""
    m = (table[..., None] == hot_ids) & (hot_ids >= 0)   # (B, NP, H)
    hit = m.any(axis=-1)
    hidx = jnp.argmax(m, axis=-1)
    hot = hot_pool.astype(vals.dtype)[hidx]              # (B, NP, KV, ps, D)
    return jnp.where(hit[..., None, None, None], hot, vals)


def _gather_pool(pool: jax.Array, table: jax.Array) -> jax.Array:
    """(P, ...) pool × (B, NP) table -> (B, NP, ...) gathered pages.
    Unmapped entries (-1) gather page 0; callers mask them via positions
    (which :func:`gather_positions` forces to -1 for unmapped pages)."""
    return pool[jnp.maximum(table, 0)]


def gather_positions(cache: PagedAttnCache) -> jax.Array:
    """(B, S_log) int32 logical-slot positions (-1 for empty/unmapped)."""
    b = cache.page_table.shape[0]
    pos = _gather_pool(cache.pos_pool, cache.page_table)  # (B, NP, ps)
    pos = jnp.where(cache.page_table[..., None] >= 0, pos, -1)
    return pos.reshape(b, cache.num_slots)


def paged_lane_view(cache: PagedAttnCache) -> AttnCache:
    """Materialize the per-lane contiguous view of a paged cache.

    The returned :class:`AttnCache` is slot-for-slot identical to what the
    contiguous cache would hold, so every reference attention core (and
    the shard_map-wrapped decode core) runs unchanged — this is the
    masked-dense/jnp fallback contract for paged serving. The Pallas
    decode kernel instead walks the page table in its ``index_map``
    (kernels/aqua_decode.aqua_paged_decode_attention) and never pays this
    gather.
    """
    b = cache.page_table.shape[0]
    s = cache.num_slots
    k = _gather_pool(cache.k_pool, cache.page_table)      # (B,NP,KV,ps,Dk)
    v = _gather_pool(cache.v_pool, cache.page_table)
    if cache.k_scale is not None:
        k = dequant_pages(k, _gather_pool(cache.k_scale, cache.page_table))
        v = dequant_pages(v, _gather_pool(cache.v_scale, cache.page_table))
        if cache.k_hot is not None:
            k = _hot_overlay(k, cache.k_hot, cache.page_table, cache.hot_ids)
            v = _hot_overlay(v, cache.v_hot, cache.page_table, cache.hot_ids)
    acc = _gather_pool(cache.acc_pool, cache.page_table)  # (B,NP,KV,ps)
    kvh = k.shape[2]
    k = k.transpose(0, 2, 1, 3, 4).reshape(b, kvh, s, k.shape[-1])
    v = v.transpose(0, 2, 1, 3, 4).reshape(b, kvh, s, v.shape[-1])
    acc = acc.transpose(0, 2, 1, 3).reshape(b, kvh, s)
    return AttnCache(k=k, v=v, positions=gather_positions(cache),
                     count=cache.count, acc_score=acc)


def paged_lane_pages(cache: PagedAttnCache, lane: jax.Array,
                     dtype=None) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Gather one lane's mapped pages as a contiguous (dequantized) view:
    ``(k (1, KV, S_log, Dk), v (1, KV, S_log, Dv), positions (1, S_log))``.
    The prefix-shared / chunked prefill path reads the already-written
    prefix through this, so quantization stays a storage detail of the
    pool. Unmapped pages read position -1 (masked by attention)."""
    tbl = cache.page_table[lane]                         # (NP,)
    phys = jnp.maximum(tbl, 0)
    pk = cache.k_pool[phys]                              # (NP, KV, ps, Dk)
    pv = cache.v_pool[phys]
    if cache.k_scale is not None:
        out_dt = jnp.float32 if dtype is None else dtype
        pk = dequant_pages(pk, cache.k_scale[phys], out_dt)
        pv = dequant_pages(pv, cache.v_scale[phys], out_dt)
        if cache.k_hot is not None:
            pk = _hot_overlay(pk[None], cache.k_hot, tbl[None],
                              cache.hot_ids)[0]
            pv = _hot_overlay(pv[None], cache.v_hot, tbl[None],
                              cache.hot_ids)[0]
    elif dtype is not None:
        pk = pk.astype(dtype)
        pv = pv.astype(dtype)
    ppos = cache.pos_pool[phys]                          # (NP, ps)
    ppos = jnp.where(tbl[:, None] >= 0, ppos, -1)
    kvh = pk.shape[1]
    s_log = cache.num_slots
    pk = pk.transpose(1, 0, 2, 3).reshape(1, kvh, s_log, -1)
    pv = pv.transpose(1, 0, 2, 3).reshape(1, kvh, s_log, -1)
    return pk, pv, ppos.reshape(1, s_log)


def paged_select_slot(cache: PagedAttnCache, *, window: Optional[int],
                      h2o: bool, recent_len: int
                      ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Paged twin of :func:`select_slot`.

    Returns ``(slot (B,), evict_page (B,) | None)``. Full-cache and ring
    policies are arithmetic-identical to the contiguous cache (the page
    table only redirects storage). H2O eviction is *page-granular*: while
    the lane still has empty slots the first one is filled; once full, the
    whole page with the smallest accumulated score (stale-first under a
    combined window, recent pages protected) is freed — ``evict_page`` is
    its logical index (-1 = no eviction this step) and the incoming token
    lands in its first slot. :func:`paged_insert` clears the victim page.
    """
    b, npl = cache.page_table.shape
    ps = cache.page_size
    s_log = cache.num_slots
    count = cache.count
    if window is not None and not h2o:
        return count % s_log, None
    if not h2o:
        return jnp.minimum(count, s_log - 1), None
    pos = gather_positions(cache)                       # (B, S_log)
    cur = count
    empty = pos < 0
    has_empty = empty.any(axis=-1)
    first_empty = jnp.argmax(empty, axis=-1).astype(jnp.int32)
    protected = pos > (cur[:, None] - recent_len)       # recent tokens
    page_prot = protected.reshape(b, npl, ps).any(axis=-1)
    acc = _gather_pool(cache.acc_pool, cache.page_table)  # (B,NP,KV,ps)
    score = acc.sum(axis=(2, 3))                        # (B, NP)
    score = jnp.where(page_prot, jnp.inf, score)
    if window is not None:
        stale = (pos >= 0) & (pos <= cur[:, None] - window)
        page_stale = stale.reshape(b, npl, ps).all(axis=-1)
        score = jnp.where(page_stale & ~page_prot, -jnp.inf, score)
    victim = jnp.argmin(score, axis=-1).astype(jnp.int32)
    slot = jnp.where(has_empty, first_empty, victim * ps)
    evict = jnp.where(has_empty, -1, victim)
    return slot, evict


def paged_insert(cache: PagedAttnCache, slot: jax.Array, k_new: jax.Array,
                 v_new: jax.Array, write_mask: Optional[jax.Array] = None,
                 evict_page: Optional[jax.Array] = None) -> PagedAttnCache:
    """Write one token's (projected/sliced) k, v at logical ``slot``.

    Physical addressing goes through the page table; suppressed writes
    (``write_mask`` False rows, unmapped pages) are redirected to an
    out-of-bounds page index and dropped (``mode="drop"``) so frozen
    lanes cost no extra HBM traffic. ``evict_page`` (page-granular H2O):
    the victim page's positions/scores are cleared *before* the write, so
    freed slots read as empty from the next step on.
    """
    b, _ = cache.page_table.shape
    ps = cache.page_size
    oob = cache.num_pages                      # dropped scatter destination
    rows = jnp.arange(b)
    entry = cache.page_table[rows, slot // ps]
    ok = entry >= 0
    if write_mask is not None:
        ok &= write_mask
    phys = jnp.where(ok, entry, oob)
    off = slot % ps

    pos_pool, acc_pool = cache.pos_pool, cache.acc_pool
    extra = {}
    if evict_page is not None:
        ev_entry = cache.page_table[rows, jnp.maximum(evict_page, 0)]
        ev_ok = (evict_page >= 0) & (ev_entry >= 0)
        if write_mask is not None:
            ev_ok &= write_mask
        ev_phys = jnp.where(ev_ok, ev_entry, oob)
        pos_pool = pos_pool.at[ev_phys].set(-1, mode="drop")
        acc_pool = acc_pool.at[ev_phys].set(0.0, mode="drop")
        if cache.k_scale is not None:
            extra["k_scale"] = cache.k_scale.at[ev_phys].set(0.0, mode="drop")
            extra["v_scale"] = cache.v_scale.at[ev_phys].set(0.0, mode="drop")
        if cache.hot_ids is not None:
            extra["hot_ids"] = _demote_residents(cache.hot_ids, ev_phys)

    if cache.k_scale is None:
        k_pool = cache.k_pool.at[phys, :, off].set(
            k_new.astype(cache.k_pool.dtype), mode="drop")
        v_pool = cache.v_pool.at[phys, :, off].set(
            v_new.astype(cache.v_pool.dtype), mode="drop")
    else:
        k_pool, extra["k_scale"] = _insert_quant_token(
            cache.k_pool, extra.get("k_scale", cache.k_scale), phys, off,
            k_new)
        v_pool, extra["v_scale"] = _insert_quant_token(
            cache.v_pool, extra.get("v_scale", cache.v_scale), phys, off,
            v_new)
        if cache.hot_ids is not None:
            # write-through: resident pages also get the exact value, so
            # the hot overlay never lags the authoritative int8 pool.
            hot_ids = extra.get("hot_ids", cache.hot_ids)
            hm = hot_ids[None, :] == phys[:, None]       # (B, H)
            hslot = jnp.where(hm.any(axis=1), jnp.argmax(hm, axis=1),
                              hot_ids.shape[0])
            extra["k_hot"] = cache.k_hot.at[hslot, :, off].set(
                k_new.astype(cache.k_hot.dtype), mode="drop")
            extra["v_hot"] = cache.v_hot.at[hslot, :, off].set(
                v_new.astype(cache.v_hot.dtype), mode="drop")
    pos_pool = pos_pool.at[phys, off].set(cache.count, mode="drop")
    acc_pool = acc_pool.at[phys, :, off].set(0.0, mode="drop")
    adv = jnp.int32(1) if write_mask is None else write_mask.astype(jnp.int32)
    return dataclasses.replace(cache, k_pool=k_pool, v_pool=v_pool,
                               pos_pool=pos_pool, acc_pool=acc_pool,
                               count=cache.count + adv, **extra)


def paged_accumulate_h2o(cache: PagedAttnCache, attn_weights: jax.Array,
                         write_mask: Optional[jax.Array] = None
                         ) -> PagedAttnCache:
    """Scatter-add the H2O statistic through the page table.

    attn_weights: (B, KV, G, S_log) probabilities over the *logical* slot
    view (what the reference decode core emits for the gathered lane
    view); summed over the G query heads per kv group. Invalid/unmapped
    slots carry zero weight (masked softmax) and unmapped pages are
    dropped scatters, so no page is polluted. Prefix-shared pages are
    incompatible with H2O (the engine rejects the combination), so no two
    lanes scatter into the same physical page.
    """
    b, npl = cache.page_table.shape
    ps = cache.page_size
    upd = attn_weights.astype(jnp.float32).sum(axis=2)  # (B, KV, S_log)
    if write_mask is not None:
        upd = jnp.where(write_mask[:, None, None], upd, 0.0)
    phys = jnp.where(cache.page_table >= 0, cache.page_table,
                     cache.num_pages)                   # (B, NP)
    phys_slot = jnp.repeat(phys, ps, axis=1)            # (B, S_log)
    off = jnp.tile(jnp.arange(ps, dtype=jnp.int32), npl)
    acc = cache.acc_pool.at[phys_slot, :, off].add(
        upd.transpose(0, 2, 1), mode="drop")
    return dataclasses.replace(cache, acc_pool=acc)


def paged_graft(cache: PagedAttnCache, req: AttnCache, lane: jax.Array,
                num_slots: int) -> PagedAttnCache:
    """Copy logical slots ``[0, num_slots)`` of a B=1 contiguous cache
    (an admission prefill) into ``lane``'s pages of the paged cache.

    Every page currently mapped by the lane is cleared first (positions
    -1, scores 0) — pool pages are recycled across requests, so stale
    positions from a previous tenant must never read as valid. The page
    table row itself is written host-side by the allocator *before* the
    jitted admission step runs (see serving.engine); this function only
    moves cache content. ``num_slots`` is static (one compile per prompt
    bucket).
    """
    ps = cache.page_size
    oob = cache.num_pages
    tbl = cache.page_table[lane]                        # (NP,)
    all_phys = jnp.where(tbl >= 0, tbl, oob)
    pos_pool = cache.pos_pool.at[all_phys].set(-1, mode="drop")
    acc_pool = cache.acc_pool.at[all_phys].set(0.0, mode="drop")

    idx = jnp.arange(num_slots)
    entry = tbl[idx // ps]
    phys = jnp.where(entry >= 0, entry, oob)
    off = idx % ps
    k_tok = req.k[0][:, idx].transpose(1, 0, 2)         # (T, KV, Dk)
    v_tok = req.v[0][:, idx].transpose(1, 0, 2)
    extra = {}
    if cache.k_scale is None:
        k_pool = cache.k_pool.at[phys, :, off].set(
            k_tok.astype(cache.k_pool.dtype), mode="drop")
        v_pool = cache.v_pool.at[phys, :, off].set(
            v_tok.astype(cache.v_pool.dtype), mode="drop")
    else:
        # per-page scales over the grafted prompt, stale scales cleared
        # for every recycled page the lane maps beyond the prompt
        k_scale = cache.k_scale.at[all_phys].set(0.0, mode="drop")
        v_scale = cache.v_scale.at[all_phys].set(0.0, mode="drop")
        ks = _page_scales(k_tok, ps, k_scale.shape[1])  # (NPG, SH)
        vs = _page_scales(v_tok, ps, v_scale.shape[1])
        npg = ks.shape[0]
        pg_phys = jnp.where(tbl[:npg] >= 0, tbl[:npg], oob)
        extra["k_scale"] = k_scale.at[pg_phys].set(ks, mode="drop")
        extra["v_scale"] = v_scale.at[pg_phys].set(vs, mode="drop")
        k_pool = cache.k_pool.at[phys, :, off].set(
            quantize_tokens(k_tok, ks[idx // ps]), mode="drop")
        v_pool = cache.v_pool.at[phys, :, off].set(
            quantize_tokens(v_tok, vs[idx // ps]), mode="drop")
        if cache.hot_ids is not None:
            # H2O precision policy: the lane's freshest page is the
            # hottest (recency-protected by eviction); promote it to a
            # full-precision residency, evicting the lowest accumulated
            # score resident. Stale residents on recycled pages drop.
            hot_ids = _demote_residents(cache.hot_ids, all_phys)
            lp = (num_slots - 1) // ps
            new_page = tbl[lp]
            res_score = jnp.where(
                hot_ids >= 0,
                acc_pool[jnp.maximum(hot_ids, 0)].sum(axis=(1, 2)),
                -jnp.inf)
            victim = jnp.argmin(res_score).astype(jnp.int32)
            vslot = jnp.where(new_page >= 0, victim, hot_ids.shape[0])
            extra["hot_ids"] = hot_ids.at[vslot].set(new_page, mode="drop")
            pad = (lp + 1) * ps - num_slots
            k_seg = jnp.pad(req.k[0][:, lp * ps:num_slots],
                            ((0, 0), (0, pad), (0, 0)))
            v_seg = jnp.pad(req.v[0][:, lp * ps:num_slots],
                            ((0, 0), (0, pad), (0, 0)))
            extra["k_hot"] = cache.k_hot.at[vslot].set(
                k_seg.astype(cache.k_hot.dtype), mode="drop")
            extra["v_hot"] = cache.v_hot.at[vslot].set(
                v_seg.astype(cache.v_hot.dtype), mode="drop")
    pos_pool = pos_pool.at[phys, off].set(req.positions[0, idx], mode="drop")
    acc_pool = acc_pool.at[phys, :, off].set(
        req.acc_score[0][:, idx].transpose(1, 0), mode="drop")
    count = cache.count.at[lane].set(req.count[0])
    return dataclasses.replace(cache, k_pool=k_pool, v_pool=v_pool,
                               pos_pool=pos_pool, acc_pool=acc_pool,
                               count=count, **extra)


def paged_write_tail(cache: PagedAttnCache, lane: jax.Array,
                     k_tail: jax.Array, v_tail: jax.Array,
                     positions: jax.Array, start_page: jax.Array,
                     new_count: jax.Array) -> PagedAttnCache:
    """Write a prefix-shared admission's *tail* K/V into ``lane``'s
    private pages, leaving the shared prefix pages untouched.

    k_tail (T, KV, Dk) / v_tail (T, KV, Dv) / positions (T,) start at the
    (page-aligned) divergence point; ``start_page`` is its logical page
    index. Tail/decode pages are cleared first (pool recycling), shared
    pages (< start_page) are read-only by construction.
    """
    ps = cache.page_size
    oob = cache.num_pages
    tbl = cache.page_table[lane]                        # (NP,)
    npl = tbl.shape[0]
    private = jnp.arange(npl) >= start_page
    clear_phys = jnp.where(private & (tbl >= 0), tbl, oob)
    pos_pool = cache.pos_pool.at[clear_phys].set(-1, mode="drop")
    acc_pool = cache.acc_pool.at[clear_phys].set(0.0, mode="drop")

    t = k_tail.shape[0]
    idx = start_page * ps + jnp.arange(t)
    entry = tbl[idx // ps]
    phys = jnp.where(entry >= 0, entry, oob)
    off = idx % ps
    extra = {}
    if cache.k_scale is None:
        k_pool = cache.k_pool.at[phys, :, off].set(
            k_tail.astype(cache.k_pool.dtype), mode="drop")
        v_pool = cache.v_pool.at[phys, :, off].set(
            v_tail.astype(cache.v_pool.dtype), mode="drop")
    else:
        # the tail starts page-aligned, so per-page scales line up with
        # tbl[start_page + i]; shared prefix pages (< start_page) keep
        # the registrant's scales untouched.
        k_scale = cache.k_scale.at[clear_phys].set(0.0, mode="drop")
        v_scale = cache.v_scale.at[clear_phys].set(0.0, mode="drop")
        ks = _page_scales(k_tail, ps, k_scale.shape[1])  # (NPG, SH)
        vs = _page_scales(v_tail, ps, v_scale.shape[1])
        npg = ks.shape[0]
        pg_tbl = tbl[start_page + jnp.arange(npg)]
        pg_phys = jnp.where(pg_tbl >= 0, pg_tbl, oob)
        extra["k_scale"] = k_scale.at[pg_phys].set(ks, mode="drop")
        extra["v_scale"] = v_scale.at[pg_phys].set(vs, mode="drop")
        tpg = jnp.arange(t) // ps
        k_pool = cache.k_pool.at[phys, :, off].set(
            quantize_tokens(k_tail, ks[tpg]), mode="drop")
        v_pool = cache.v_pool.at[phys, :, off].set(
            quantize_tokens(v_tail, vs[tpg]), mode="drop")
        if cache.hot_ids is not None:
            extra["hot_ids"] = _demote_residents(cache.hot_ids, clear_phys)
    pos_pool = pos_pool.at[phys, off].set(positions, mode="drop")
    count = cache.count.at[lane].set(new_count)
    return dataclasses.replace(cache, k_pool=k_pool, v_pool=v_pool,
                               pos_pool=pos_pool, acc_pool=acc_pool,
                               count=count, **extra)


def paged_reset_lane(cache: PagedAttnCache, lane: jax.Array
                     ) -> PagedAttnCache:
    """Restore ``lane`` to the empty condition: clear its mapped pages,
    unmap the table row, zero its count. (Host-side page *deallocation*
    is the allocator's job; this clears device state.)"""
    oob = cache.num_pages
    tbl = cache.page_table[lane]
    phys = jnp.where(tbl >= 0, tbl, oob)
    extra = {}
    if cache.k_scale is not None:
        extra["k_scale"] = cache.k_scale.at[phys].set(0.0, mode="drop")
        extra["v_scale"] = cache.v_scale.at[phys].set(0.0, mode="drop")
    if cache.hot_ids is not None:
        extra["hot_ids"] = _demote_residents(cache.hot_ids, phys)
    return dataclasses.replace(
        cache,
        pos_pool=cache.pos_pool.at[phys].set(-1, mode="drop"),
        acc_pool=cache.acc_pool.at[phys].set(0.0, mode="drop"),
        page_table=cache.page_table.at[lane].set(-1),
        count=cache.count.at[lane].set(0), **extra)


def paged_copy_page(cache: PagedAttnCache, src: jax.Array, dst: jax.Array
                    ) -> PagedAttnCache:
    """Device-side companion of the host allocator's copy-on-write
    ``PagePool.make_private``: duplicate physical page ``src`` into the
    freshly-reserved ``dst``. K/V content, positions, H2O scores and (for
    quantized pools) the per-page scale metadata ride together, so a
    privatized copy dequantizes bit-identically to the shared original."""
    cp = lambda pool: pool.at[dst].set(pool[src])
    extra = {}
    if cache.k_scale is not None:
        extra = dict(k_scale=cp(cache.k_scale), v_scale=cp(cache.v_scale))
    return dataclasses.replace(
        cache, k_pool=cp(cache.k_pool), v_pool=cp(cache.v_pool),
        pos_pool=cp(cache.pos_pool), acc_pool=cp(cache.acc_pool), **extra)


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of (abstract or concrete) arrays — the
    single source of truth for cache-footprint accounting (both serving
    engines' ``cache_bytes`` and the benches go through this)."""
    return sum(math.prod(a.shape) * a.dtype.itemsize
               for a in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# SSM / recurrent caches
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclass
class SSMCache:
    """Mamba-2 per-layer state: rolling conv window + SSD state."""

    conv: jax.Array   # (B, conv_width-1, conv_channels)
    state: jax.Array  # (B, nheads, head_dim, state_dim)
    count: jax.Array  # (B,)


@jax.tree_util.register_dataclass
@dataclass
class RGLRUCache:
    """RecurrentGemma recurrent-block state."""

    conv: jax.Array   # (B, conv_width-1, lru_width)
    state: jax.Array  # (B, lru_width) real-gated LRU hidden state
    count: jax.Array
