"""Unified serving-dispatch plan: one resolved, inspectable decision.

The serving engine used to scatter its dispatch state across an
``engine._kernel_native`` bool, ad-hoc ``kernel_shardable(...)`` call
sites, and per-engine ``_mesh_fallback`` set reads — with the fallback
*reason* strings duplicated between ``core/attention.py`` and the engine
(a wording drift silently split one dedup key into two warning events).
:class:`DispatchPlan` replaces that: resolved once per engine from the
same predicates the attention dispatch applies at trace time, frozen,
and exposed as the one public inspection point
(``ContinuousBatchingEngine.dispatch_plan()``). The reason constants
below are the *single source* for every fallback string — the attention
dispatch logs them verbatim, the warning-dedup sink keys off them, and
the README backend×mesh matrix (``launch/matrix.py``) renders them.

Resolution is geometry- and policy-complete but trace-free: the plan
predicts exactly what ``repro.core.attention`` will dispatch, and the
engine's ``mesh_fallback_events()`` (trace-time truth) stays empty iff
the plan said ``mesh_native=True``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Cache layouts a plan can pick.
CACHE_CONTIGUOUS = "contiguous"
CACHE_PAGED = "paged"

# Canonical fallback-reason vocabulary. These exact strings key the
# warning dedup in ``attention._log_mesh_kernel_fallback`` and appear in
# ``DispatchPlan.reasons`` — never inline a variant wording at a dispatch
# site (that is the drift this module exists to end).
REASON_NO_MESH = "no serving mesh installed"
REASON_REFERENCE_BACKEND = "backend has no Pallas decode kernel"
REASON_PER_DIM_SELECTION = (
    "block_dims <= 1 keeps the paper's per-dim selection "
    "(masked-dense semantics)")
REASON_WINDOW = "sliding-window policy needs per-slot position masking"
REASON_H2O = "H2O eviction needs the reference path's dense weights"
REASON_NONDIVISIBLE_MESH = "axis extents don't divide the serving mesh"
REASON_PAGE_GEOMETRY = (
    "page size doesn't tile into the kernel's 8-token sequence blocks")
REASON_QUANT_RESIDENCY = (
    "mixed-precision hot residents need the reference path's "
    "dequantized lane view")
REASON_QUANT_GEOMETRY = (
    "quantized pages only decode through the paged kernel's scale-folded "
    "path; this layout/backend combination dequantizes via the reference "
    "lane view")
# Chunked-prefill attribution (``DispatchPlan.chunked_prefill``): why an
# engine keeps monolithic admission even though interleaving exists.
REASON_NO_PREFILL_BUDGET = "no prefill_budget_tokens configured"
REASON_FRONTEND = (
    "modality frontend splices non-token embeddings at prefill time")
REASON_MOE_CAPACITY = (
    "MoE capacity routing is batch-shape dependent; chunk boundaries "
    "would change which tokens drop")
REASON_FAMILY_SURGERY = (
    "model family lacks chunk-resumable lane surgery (recurrent state "
    "is not a slot cache)")
REASON_CHUNK_GEOMETRY = (
    "prefill budget is not a multiple of the kernel's q-chunk tile — "
    "chunk boundaries would change the dim-block selection")
# Hierarchical token-sparsity attribution (``DispatchPlan.token_sparsity``):
# why an engine configured with ``page_keep_ratio < 1`` still attends
# every page. (Hierarchical-without-paged is a *config* error —
# ``configs.base.resolve_sparsity_spec`` rejects it before dispatch.)
REASON_TOKEN_WINDOW = (
    "sliding-window policy already bounds the token set; page-granular "
    "participation would double-mask it")
REASON_TOKEN_H2O = (
    "H2O eviction reshapes the page set mid-flight; page participation "
    "needs a stable table within a step")


@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """The engine's resolved serving-dispatch decision.

    backend:        resolved attention backend name (after the
                    ``resolve_backend`` fallback policy), or ``"none"``
                    for attention-free families.
    cache_layout:   :data:`CACHE_CONTIGUOUS` or :data:`CACHE_PAGED`.
    quantization:   resolved KV-pool precision mode — ``"none"`` (full
                    precision), ``"int8"`` (per-page symmetric quantized
                    pools), or ``"int8-mixed"`` (int8 plus H2O-hot
                    full-precision residents); ``QuantSpec.mode``.
    mesh_native:    True when decode serves through the shard_mapped
                    Pallas kernel path (and the cache is laid out for
                    it) — the contract ``launch.serve
                    --expect-kernel-mesh`` gates on.
    prefix_sharing: True when paged admissions share page-aligned prompt
                    prefixes (policy + layout admit it).
    reasons:        why ``mesh_native`` is False — a tuple of the
                    REASON_* constants above, in check order; empty iff
                    ``mesh_native``.
    token_sparsity: resolved hierarchical (two-stage) selection mode —
                    ``"none"`` (every page participates) or
                    ``"hierarchical"`` (stage-1 page-granular
                    participation from ``SparsitySpec.page_keep_ratio``,
                    stage-2 dim-block top-k within participants). Both
                    the shard_mapped kernel path and the masked-dense
                    reference honor the same participating-page set, so
                    this is a *selection* mode, not a dispatch fork.
    token_reasons:  why ``token_sparsity`` is ``"none"`` despite a
                    hierarchical ``SparsitySpec`` — REASON_TOKEN_*
                    constants in check order; empty when the config
                    didn't ask for token sparsity at all.
    chunked_prefill: True when admissions longer than the configured
                    ``prefill_budget_tokens`` are split into page-aligned
                    chunks interleaved with decode steps (the PREFILLING
                    lane state). False falls back to monolithic admission
                    — the whole prefill runs inside the admit.
    chunked_reasons: why ``chunked_prefill`` is False, in check order;
                    empty iff ``chunked_prefill``.
    """

    backend: str
    cache_layout: str
    mesh_native: bool
    prefix_sharing: bool
    reasons: Tuple[str, ...] = ()
    chunked_prefill: bool = False
    chunked_reasons: Tuple[str, ...] = ()
    quantization: str = "none"
    token_sparsity: str = "none"
    token_reasons: Tuple[str, ...] = ()

    @property
    def paged(self) -> bool:
        return self.cache_layout == CACHE_PAGED


def resolve_dispatch_plan(*, attention, aqua, serving, mesh,
                          prefix_sharing: bool = False,
                          batch: Optional[int] = None,
                          family: str = "dense",
                          frontend: str = "none") -> DispatchPlan:
    """Resolve the dispatch plan the attention product will follow.

    ``attention``/``aqua`` are the model's configs (post any per-engine
    backend override), ``serving`` a ``ServingConfig``, ``mesh`` the
    serving mesh or None. ``batch`` overrides the decode batch size
    (default ``serving.max_lanes``). ``prefix_sharing`` is the engine's
    effective prefix decision (it folds in model-capability checks the
    config alone can't see), recorded verbatim. ``family``/``frontend``
    are the model family and frontend kind — the chunked-prefill
    predicate needs them (chunk boundaries must not change what a token
    computes, which capacity-routed MoE and embedding-splicing frontends
    cannot promise).

    Imports are deferred: ``core.attention`` imports this module for the
    reason constants, so the reverse dependency must stay lazy.
    """
    from repro.configs.base import resolve_cache_specs, resolve_sparsity_spec
    from repro.core.attention import resolve_backend
    from repro.core.h2o import h2o_budget
    from repro.distributed import sharding as dsh

    cache_spec, quant_spec = resolve_cache_specs(serving, warn=False)
    sparsity_spec = resolve_sparsity_spec(serving)
    paged = cache_spec.paged
    cache_layout = CACHE_PAGED if paged else CACHE_CONTIGUOUS
    quant_mode = quant_spec.mode
    if batch is None:
        batch = serving.max_lanes
    reasons = []
    if attention is None:
        backend_name = "none"
        be = None
    else:
        be = resolve_backend(attention.backend, aqua=aqua)
        backend_name = be.name
    if mesh is None:
        reasons.append(REASON_NO_MESH)
    decode_fn = None
    if be is not None:
        decode_fn = be.paged_decode if paged else be.decode
    if be is None or not (be.requires_pallas and decode_fn is not None):
        reasons.append(REASON_REFERENCE_BACKEND)
    else:
        aqua_on = aqua is not None and aqua.enabled
        if aqua_on and aqua.block_dims <= 1:
            reasons.append(REASON_PER_DIM_SELECTION)
        if attention.window is not None:
            reasons.append(REASON_WINDOW)
        if aqua_on and h2o_budget(aqua, serving.max_seq) is not None:
            reasons.append(REASON_H2O)
        if quant_spec.quantized and quant_spec.hot_resident_fraction > 0:
            reasons.append(REASON_QUANT_RESIDENCY)
        if mesh is not None and not dsh.kernel_shardable(
                mesh, attention, aqua, batch=batch,
                page_size=cache_spec.page_size):
            if (cache_spec.page_size is not None
                    and cache_spec.page_size % dsh.KERNEL_PAGE_MULTIPLE != 0):
                reasons.append(REASON_PAGE_GEOMETRY)
            else:
                reasons.append(REASON_NONDIVISIBLE_MESH)
    # Quantized pages have no dequantizing kernel outside the paged
    # scale-folded path: attribute the extra cost whenever another
    # predicate already forces the reference lane view.
    if quant_mode != "none" and any(
            r not in (REASON_NO_MESH, REASON_QUANT_RESIDENCY)
            for r in reasons):
        reasons.append(REASON_QUANT_GEOMETRY)
    mesh_native = mesh is not None and not reasons

    # Chunked-prefill interleaving: admissible only where splitting the
    # prefill at an arbitrary page boundary provably computes the same
    # tokens as the monolithic pass (full-cache slot placement, no
    # batch-shape-dependent routing, token-only inputs).
    chunked_reasons = []
    if serving.prefill_budget_tokens is None:
        chunked_reasons.append(REASON_NO_PREFILL_BUDGET)
    if attention is None or family not in ("dense", "vlm", "moe"):
        chunked_reasons.append(REASON_FAMILY_SURGERY)
    elif family == "moe":
        chunked_reasons.append(REASON_MOE_CAPACITY)
    if frontend != "none":
        chunked_reasons.append(REASON_FRONTEND)
    if attention is not None:
        if attention.window is not None:
            chunked_reasons.append(REASON_WINDOW)
        if (aqua is not None and aqua.enabled
                and h2o_budget(aqua, serving.max_seq) is not None):
            chunked_reasons.append(REASON_H2O)
        # block-sparse kernel prefill aggregates |q̂| per q-chunk tile:
        # chunk cursors must land on tile boundaries or a straddling
        # tile would select different dim-blocks than the monolithic
        # invocation (identity broken, not just delayed)
        if (serving.prefill_budget_tokens is not None
                and backend_name == "aqua-block-sparse"
                and aqua is not None and aqua.enabled
                and aqua.block_dims > 1
                and serving.prefill_budget_tokens % aqua.prefill_q_blk != 0):
            chunked_reasons.append(REASON_CHUNK_GEOMETRY)

    # Hierarchical token sparsity: a selection mode, not a dispatch fork —
    # the kernel streams only participating pages, the masked-dense
    # reference masks the same set, so it engages independently of
    # ``mesh_native``. Only policies that rewrite the token set mid-step
    # (window masking, H2O eviction) veto it.
    token_reasons = []
    if sparsity_spec.hierarchical and attention is not None:
        if attention.window is not None:
            token_reasons.append(REASON_TOKEN_WINDOW)
        if (aqua is not None and aqua.enabled
                and h2o_budget(aqua, serving.max_seq) is not None):
            token_reasons.append(REASON_TOKEN_H2O)
    hierarchical = (sparsity_spec.hierarchical and attention is not None
                    and not token_reasons)

    return DispatchPlan(backend=backend_name, cache_layout=cache_layout,
                        mesh_native=mesh_native,
                        prefix_sharing=bool(prefix_sharing),
                        reasons=tuple(reasons),
                        chunked_prefill=not chunked_reasons,
                        chunked_reasons=tuple(chunked_reasons),
                        quantization=quant_mode,
                        token_sparsity=("hierarchical" if hierarchical
                                        else "none"),
                        token_reasons=tuple(token_reasons))
