"""H2O heavy-hitter token eviction (Zhang et al., 2023) and its AQUA
coupling (paper §8.3).

The slot mechanics live in ``repro.core.kvcache`` (select_slot /
accumulate_h2o); this module provides the policy-level API and a reference
"oracle" implementation used by tests and the Table-2 benchmark:
given a full attention-weight history, which tokens would H2O keep?
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AquaConfig
from repro.core import kvcache as kv


def h2o_budget(aqua: Optional[AquaConfig], max_seq: int) -> Optional[int]:
    if aqua is None or not aqua.enabled or aqua.h2o_ratio >= 1.0:
        return None
    return max(8, int(aqua.h2o_ratio * max_seq))


def reference_keep_set(weights: jax.Array, budget: int, recent_frac: float
                       ) -> jax.Array:
    """Oracle H2O keep-set from a full (S_q, S_k) attention-weight matrix
    (single head). Returns sorted kept indices of size ``budget``.

    Used to validate the online slot-based policy: after processing a
    sequence, the cache's kept positions must match this set's semantics
    (heavy hitters by accumulated score + recent window).
    """
    s = weights.shape[-1]
    recent = max(1, int(recent_frac * budget))
    acc = weights.sum(axis=0)                      # accumulated column mass
    acc = acc.at[s - recent:].set(jnp.inf)         # recents always kept
    _, idx = jax.lax.top_k(acc, budget)
    return jnp.sort(idx)


def eviction_step(cache: kv.AttnCache, aqua: AquaConfig) -> jax.Array:
    """Expose the victim-selection decision for inspection/benchmarks."""
    recent_len = max(1, int(aqua.h2o_recent_frac * cache.num_slots))
    return kv.select_slot(cache, window=None, h2o=True, recent_len=recent_len)


# ---------------------------------------------------------------------------
# Page-granular H2O (paged KV cache)
# ---------------------------------------------------------------------------


def reference_victim_page(positions, acc_score, count, *, page_size: int,
                          recent_len: int, window=None):
    """NumPy oracle for the paged H2O victim-page decision (single lane).

    positions: (S,) logical-slot positions (-1 empty); acc_score: (KV, S);
    count: scalar position of the incoming token. Returns the logical page
    index that ``kvcache.paged_select_slot`` must evict, or -1 when an
    empty slot exists (no eviction). Independent implementation used by
    the property-based cache-invariant suite.
    """
    import numpy as np

    pos = np.asarray(positions)
    acc = np.asarray(acc_score, np.float32)   # match the device dtype
    s = pos.shape[0]
    npl = s // page_size
    if (pos < 0).any():
        return -1
    protected = pos > (count - recent_len)
    page_prot = protected.reshape(npl, page_size).any(axis=-1)
    score = acc.sum(axis=0).reshape(npl, page_size).sum(axis=-1)
    score = np.where(page_prot, np.inf, score)
    if window is not None:
        stale = (pos >= 0) & (pos <= count - window)
        page_stale = stale.reshape(npl, page_size).all(axis=-1)
        score = np.where(page_stale & ~page_prot, -np.inf, score)
    return int(np.argmin(score))
