"""AQUA block-sparse decode-attention Pallas TPU kernel.

TPU-native realization of the paper's magnitude-pruned score computation
(DESIGN.md §2): the projected key cache is stored **dim-major**
(B, KV, NB_total, bd, S) — dim-blocks of ``bd`` sublanes × a long lane-dim
sequence stripe. Per query head, only the ``NB_sel`` dim-blocks selected by
query magnitude are DMA'd HBM→VMEM, via ``PrefetchScalarGridSpec``: the
selected block indices are scalar-prefetched and dereferenced inside the
K BlockSpec ``index_map``. HBM score-read traffic drops to
``NB_sel / NB_total = k_ratio`` of baseline — the decode roofline is
memory-bound, so this is the term the paper's technique buys down on TPU.

The value product and online softmax are fused flash-decode style, so the
(B, H, S) score matrix never materializes in HBM.

Grid: (B, H, num_seq_blocks, NB_sel)  — dim-block index j innermost; the
V block index_map is constant in j, so Pallas keeps the V tile resident
across the j loop (single fetch per seq block).

Mesh-native serving runs this kernel *inside* ``shard_map``
(``repro.core.attention.shard_mapped_decode_kernel``): B and H are then
shard-local lane/head extents while the slot axis S stays whole per
shard — the engine's kernel-native cache layout never slot-shards or
dim-splits the K̂ stripes, so the scalar-prefetched block-index tables
and the ``NB_sel``/``NB_total`` accounting are purely shard-local.

The paged kernel (:func:`aqua_paged_decode_attention`) rides the same
machinery shard_mapped (``shard_mapped_paged_decode_kernel``): the
page-table rows it scalar-prefetches are the shard's own lane group's —
tables partition with their lanes over the data axes — while the page
pool arrives with its page axis whole per data shard (pages are
lane-global; ``model`` only partitions the pool's KV-head axis, so whole
pages and whole dim-blocks ride with each head). Table entries are
pool-global page ids valid unchanged on every shard, so the ``index_map``
page dereference needs no translation and no collective — exactly like
the contiguous kernel's dim-block indices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(idx_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            s_ref, m_ref, l_ref, acc_ref, *, scale: float, seq_blk: int,
            nb_sel: int, nsb: int):
    b = pl.program_id(0)
    sb = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when((sb == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j == 0)
    def _reset_scores():
        s_ref[...] = jnp.zeros_like(s_ref)

    # partial scores for this selected dim-block: (1, bd) @ (bd, S_blk)
    q_blk = q_ref[0, 0].astype(jnp.float32)          # (1, bd)
    k_blk = k_ref[0, 0, 0].astype(jnp.float32)       # (bd, S_blk)
    s_ref[...] += jax.lax.dot_general(
        q_blk, k_blk, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == nb_sel - 1)
    def _finalize_block():
        s = s_ref[...] * scale                        # (1, S_blk)
        pos = sb * seq_blk + jax.lax.broadcasted_iota(jnp.int32, (1, seq_blk),
                                                      1)
        valid = pos < len_ref[b]
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new)                        # (1, S_blk)
        corr = jnp.exp(m_prev - m_new)
        l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p)
        v_blk = v_ref[0, 0].astype(jnp.float32)       # (S_blk, Dv)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[0, 0] = m_new

        @pl.when(sb == nsb - 1)
        def _write():
            o_ref[...] = (acc_ref[...] /
                          jnp.maximum(l_ref[0, 0], 1e-30)
                          ).astype(o_ref.dtype)[None]


def _paged_kernel(idx_ref, pt_ref, len_ref, *rest, **kw):
    """Paged twin of :func:`_kernel`: the kernel body is identical (the
    page table is consumed only by the BlockSpec ``index_map``s), so the
    extra scalar-prefetch ref is simply dropped here."""
    del pt_ref
    _kernel(idx_ref, len_ref, *rest, **kw)


def _paged_part_kernel(idx_ref, pt_ref, part_ref, len_ref,
                       q_ref, k_ref, v_ref, o_ref,
                       s_ref, m_ref, l_ref, acc_ref, *, scale: float,
                       seq_blk: int, nb_sel: int, nsb: int, bpp: int):
    """Hierarchical (two-stage) twin of :func:`_paged_kernel`.

    The grid's sequence-block axis runs over *participating* pages only
    (``nsb = KP * bpp``); ``part_ref`` (B, KP) maps each grid step to its
    logical page so the position validity test stays token-exact. Pages
    the stage-1 ranking dropped are never touched — their HBM bytes are
    simply not streamed (the BlockSpec ``index_map`` never emits them)."""
    b = pl.program_id(0)
    sb = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when((sb == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j == 0)
    def _reset_scores():
        s_ref[...] = jnp.zeros_like(s_ref)

    q_blk = q_ref[0, 0].astype(jnp.float32)          # (1, bd)
    k_blk = k_ref[0, 0, 0].astype(jnp.float32)       # (bd, S_blk)
    s_ref[...] += jax.lax.dot_general(
        q_blk, k_blk, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == nb_sel - 1)
    def _finalize_block():
        s = s_ref[...] * scale                        # (1, S_blk)
        lp = part_ref[b, sb // bpp]                   # logical page id
        pos = (lp * bpp + sb % bpp) * seq_blk + jax.lax.broadcasted_iota(
            jnp.int32, (1, seq_blk), 1)
        valid = pos < len_ref[b]
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new)                        # (1, S_blk)
        corr = jnp.exp(m_prev - m_new)
        l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p)
        v_blk = v_ref[0, 0].astype(jnp.float32)       # (S_blk, Dv)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[0, 0] = m_new

        @pl.when(sb == nsb - 1)
        def _write():
            o_ref[...] = (acc_ref[...] /
                          jnp.maximum(l_ref[0, 0], 1e-30)
                          ).astype(o_ref.dtype)[None]


def _paged_part_quant_kernel(idx_ref, pt_ref, part_ref, len_ref,
                             ks_ref, vs_ref, q_ref, k_ref, v_ref, o_ref,
                             s_ref, m_ref, l_ref, acc_ref, *, scale: float,
                             seq_blk: int, nb_sel: int, nsb: int, bpp: int,
                             g: int, s_stride: int):
    """Hierarchical int8 variant: :func:`_paged_part_kernel`'s logical-page
    remap composed with :func:`_paged_quant_kernel`'s scale folding — the
    per-page scales are looked up through the participating page's table
    entry, positions through its logical index."""
    b = pl.program_id(0)
    h = pl.program_id(1)
    sb = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when((sb == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j == 0)
    def _reset_scores():
        s_ref[...] = jnp.zeros_like(s_ref)

    q_blk = q_ref[0, 0].astype(jnp.float32)          # (1, bd)
    k_blk = k_ref[0, 0, 0].astype(jnp.float32)       # (bd, S_blk) int->f32
    s_ref[...] += jax.lax.dot_general(
        q_blk, k_blk, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == nb_sel - 1)
    def _finalize_block():
        lp = part_ref[b, sb // bpp]                   # logical page id
        page = jnp.maximum(pt_ref[b, lp], 0)
        kv = (h // g) * s_stride
        s = s_ref[...] * (scale * ks_ref[page, kv])   # (1, S_blk)
        pos = (lp * bpp + sb % bpp) * seq_blk + jax.lax.broadcasted_iota(
            jnp.int32, (1, seq_blk), 1)
        valid = pos < len_ref[b]
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new)                        # (1, S_blk)
        corr = jnp.exp(m_prev - m_new)
        l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p)
        v_blk = v_ref[0, 0].astype(jnp.float32) * vs_ref[page, kv]
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[0, 0] = m_new

        @pl.when(sb == nsb - 1)
        def _write():
            o_ref[...] = (acc_ref[...] /
                          jnp.maximum(l_ref[0, 0], 1e-30)
                          ).astype(o_ref.dtype)[None]


def _paged_quant_kernel(idx_ref, pt_ref, len_ref, ks_ref, vs_ref,
                        q_ref, k_ref, v_ref, o_ref,
                        s_ref, m_ref, l_ref, acc_ref, *, scale: float,
                        seq_blk: int, nb_sel: int, nsb: int, bpp: int,
                        g: int, s_stride: int):
    """int8 paged variant: dequant-free score accumulation.

    The int8 K̂ tiles feed the same dot_general (upcast in-register); the
    per-page key scale is *folded into the softmax scale* at finalize —
    every sequence block lives inside exactly one physical page, so one
    scalar multiply replaces a per-element dequant of the K tile. V tiles
    dequantize once per (b, h, sb) with their page's scalar. The scales
    ride scalar prefetch (SMEM) like the page table; ``s_stride`` is 1
    for per-(page, head) scales and 0 for one-scale-per-page."""
    b = pl.program_id(0)
    h = pl.program_id(1)
    sb = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when((sb == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j == 0)
    def _reset_scores():
        s_ref[...] = jnp.zeros_like(s_ref)

    q_blk = q_ref[0, 0].astype(jnp.float32)          # (1, bd)
    k_blk = k_ref[0, 0, 0].astype(jnp.float32)       # (bd, S_blk) int->f32
    s_ref[...] += jax.lax.dot_general(
        q_blk, k_blk, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == nb_sel - 1)
    def _finalize_block():
        page = jnp.maximum(pt_ref[b, sb // bpp], 0)
        kv = (h // g) * s_stride
        s = s_ref[...] * (scale * ks_ref[page, kv])   # (1, S_blk)
        pos = sb * seq_blk + jax.lax.broadcasted_iota(jnp.int32, (1, seq_blk),
                                                      1)
        valid = pos < len_ref[b]
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new)                        # (1, S_blk)
        corr = jnp.exp(m_prev - m_new)
        l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p)
        v_blk = v_ref[0, 0].astype(jnp.float32) * vs_ref[page, kv]
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[0, 0] = m_new

        @pl.when(sb == nsb - 1)
        def _write():
            o_ref[...] = (acc_ref[...] /
                          jnp.maximum(l_ref[0, 0], 1e-30)
                          ).astype(o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("block_dims", "seq_blk",
                                             "scale", "interpret"))
def aqua_paged_decode_attention(q_sel: jax.Array, khat_pages: jax.Array,
                                v_pages: jax.Array, block_idx: jax.Array,
                                page_table: jax.Array, lengths: jax.Array,
                                k_scale=None, v_scale=None, part_idx=None,
                                *, block_dims: int = 8, seq_blk: int = 128,
                                scale=None, interpret=None) -> jax.Array:
    """Block-sparse AQUA decode attention over a *paged* K/V pool.

    q_sel:       (B, H, NB_sel, bd)  — query, pre-gathered selected blocks
    khat_pages:  (P, KV, NB_total, bd, ps) — dim-major projected key pool
                 (page-major: each physical page holds a ``ps``-token
                 dim-major stripe)
    v_pages:     (P, KV, ps, Dv)
    block_idx:   (B, H, NB_sel) int32 — selected dim-block ids (sorted)
    page_table:  (B, NP_lane) int32 — physical page of each logical page,
                 -1 unmapped (clamped; masked off via ``lengths``)
    lengths:     (B,) int32 — valid cache length per row. Full-cache
                 policy only: logical slot == token position.
    k_scale, v_scale: (P, SH) f32 per-page scales for int8 pools (SH ∈
                 {KV, 1}); both None for full-precision pools.
    part_idx:    (B, KP) int32 — stage-1 *participating* logical page
                 indices per lane, sorted ascending
                 (``core.selection.participating_pages``), or None to
                 attend every page. Entries must be valid logical indices
                 in [0, NP_lane); pages past the lane's length contribute
                 nothing (position masking). When given, the grid's
                 sequence-block extent shrinks from NP_lane to KP — the
                 dropped pages' K̂/V tiles are never streamed from HBM.
    returns out: (B, H, Dv)

    The page table is the second scalar-prefetch operand: the K and V
    ``index_map``s dereference it to locate the physical page of each
    sequence block — the same scalar-prefetch indirection the dim-block
    selection already uses, composed on the sequence axis. HBM traffic is
    unchanged vs the contiguous kernel (pages only redirect addressing);
    the pool itself is what shrinks (repro.core.kvcache.PagedAttnCache).

    Quantized pools compose on the same machinery: the per-page scales
    are scalar-prefetch operands 4/5, the int8 K̂ tile feeds the MXU
    upcast in-register, and the key scale folds into the softmax scale at
    finalize — no dequantized K/V page ever materializes
    (:func:`_paged_quant_kernel`). HBM score-read traffic drops a further
    4× vs bf16 pools (1 byte/elem), compounding with the ``k_ratio``
    dim-sparsity term.

    Shard-local contract: under a serving mesh this runs inside
    ``shard_map`` with B the shard's lane-group extent and ``page_table``
    that group's rows, while ``khat_pages``/``v_pages`` keep their page
    axis whole (P is pool-global; only KV is shard-local, over ``model``).
    The entries of ``page_table`` are pool-global page ids, so the
    ``index_map`` dereference above is valid verbatim on every shard.
    """
    from repro import runtime_flags as _rtf
    b, h, nb_sel, bd = q_sel.shape
    _, kvh, nb_total, bd2, ps = khat_pages.shape
    assert bd == bd2 == block_dims
    npl = page_table.shape[1]
    dv = v_pages.shape[-1]
    g = h // kvh
    assert ps % seq_blk == 0, (ps, seq_blk)
    bpp = ps // seq_blk                       # sequence blocks per page
    hier = part_idx is not None
    nsb = (part_idx.shape[1] if hier else npl) * bpp
    if scale is None:
        scale = 1.0 / ((nb_total * bd) ** 0.5)
    interpret = _rtf.resolve_interpret(interpret)

    grid = (b, h, nsb, nb_sel)
    quant = k_scale is not None
    nsp = (3 if not quant else 5) + (1 if hier else 0)

    # trailing scalar-prefetch refs: (idx, pt[, part], len[, ks, vs]) —
    # the maps only dereference idx/pt/part, so *refs covers all arities.
    def q_map(bi, hi, sbi, ji, *refs):
        return (bi, hi, ji, 0)

    if hier:
        # sequence-block axis walks participating pages only: grid step
        # sbi -> logical page part[bi, sbi // bpp] -> physical page.
        def k_map(bi, hi, sbi, ji, *refs):
            lp = refs[2][bi, sbi // bpp]
            page = jnp.maximum(refs[1][bi, lp], 0)
            return (page, hi // g, refs[0][bi, hi, ji], 0, sbi % bpp)

        def v_map(bi, hi, sbi, ji, *refs):
            lp = refs[2][bi, sbi // bpp]
            page = jnp.maximum(refs[1][bi, lp], 0)
            return (page, hi // g, sbi % bpp, 0)
    else:
        def k_map(bi, hi, sbi, ji, *refs):
            page = jnp.maximum(refs[1][bi, sbi // bpp], 0)
            return (page, hi // g, refs[0][bi, hi, ji], 0, sbi % bpp)

        def v_map(bi, hi, sbi, ji, *refs):
            page = jnp.maximum(refs[1][bi, sbi // bpp], 0)
            return (page, hi // g, sbi % bpp, 0)

    def o_map(bi, hi, sbi, ji, *refs):
        return (bi, hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=nsp,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, bd), q_map),
            pl.BlockSpec((1, 1, 1, bd, seq_blk), k_map),
            pl.BlockSpec((1, 1, seq_blk, dv), v_map),
        ],
        out_specs=pl.BlockSpec((1, 1, dv), o_map),
        scratch_shapes=[
            pltpu.VMEM((1, seq_blk), jnp.float32),   # score accumulator
            pltpu.VMEM((1, 1), jnp.float32),         # running max
            pltpu.VMEM((1, 1), jnp.float32),         # running denom
            pltpu.VMEM((1, dv), jnp.float32),        # output accumulator
        ],
    )
    if quant:
        common = dict(scale=scale, seq_blk=seq_blk, nb_sel=nb_sel, nsb=nsb,
                      bpp=bpp, g=g,
                      s_stride=1 if k_scale.shape[1] > 1 else 0)
        # int8 pools can't carry the output dtype; accumulate/emit f32.
        out_dtype = jnp.float32
        scales = (k_scale.astype(jnp.float32), v_scale.astype(jnp.float32))
        if hier:
            kernel = functools.partial(_paged_part_quant_kernel, **common)
            operands = (block_idx, page_table, part_idx, lengths, *scales,
                        q_sel, khat_pages, v_pages)
        else:
            kernel = functools.partial(_paged_quant_kernel, **common)
            operands = (block_idx, page_table, lengths, *scales,
                        q_sel, khat_pages, v_pages)
    else:
        out_dtype = v_pages.dtype
        if hier:
            kernel = functools.partial(_paged_part_kernel, scale=scale,
                                       seq_blk=seq_blk, nb_sel=nb_sel,
                                       nsb=nsb, bpp=bpp)
            operands = (block_idx, page_table, part_idx, lengths, q_sel,
                        khat_pages, v_pages)
        else:
            kernel = functools.partial(_paged_kernel, scale=scale,
                                       seq_blk=seq_blk, nb_sel=nb_sel,
                                       nsb=nsb)
            operands = (block_idx, page_table, lengths, q_sel, khat_pages,
                        v_pages)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, dv), out_dtype),
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit, static_argnames=("block_dims", "seq_blk",
                                             "scale", "interpret"))
def aqua_decode_attention(q_sel: jax.Array, khat_blocks: jax.Array,
                          v: jax.Array, block_idx: jax.Array,
                          lengths: jax.Array, *, block_dims: int = 8,
                          seq_blk: int = 128, scale=None,
                          interpret=None) -> jax.Array:
    """Block-sparse AQUA decode attention.

    q_sel:       (B, H, NB_sel, bd)  — query, pre-gathered selected blocks
    khat_blocks: (B, KV, NB_total, bd, S) — dim-major projected key cache
    v:           (B, KV, S, Dv)
    block_idx:   (B, H, NB_sel) int32 — selected dim-block ids (sorted)
    lengths:     (B,) int32 — valid cache length per row
    scale:       score scale; defaults to 1/sqrt(NB_total * bd). Pass
                 1/sqrt(head_dim) when k̂ is statically sliced (AQUA-Memory)
                 — the paper approximates *full* head-dim scores.
    interpret:   None -> resolved by runtime_flags (compiled iff on TPU)
    returns out: (B, H, Dv)
    """
    from repro import runtime_flags as _rtf
    b, h, nb_sel, bd = q_sel.shape
    _, kvh, nb_total, bd2, s = khat_blocks.shape
    assert bd == bd2 == block_dims
    dv = v.shape[-1]
    g = h // kvh
    assert s % seq_blk == 0, (s, seq_blk)
    nsb = s // seq_blk
    if scale is None:
        # scale by the FULL head-dim sqrt of the projected cache.
        scale = 1.0 / ((nb_total * bd) ** 0.5)
    interpret = _rtf.resolve_interpret(interpret)

    grid = (b, h, nsb, nb_sel)

    def q_map(bi, hi, sbi, ji, idx_ref, len_ref):
        return (bi, hi, ji, 0)

    def k_map(bi, hi, sbi, ji, idx_ref, len_ref):
        return (bi, hi // g, idx_ref[bi, hi, ji], 0, sbi)

    def v_map(bi, hi, sbi, ji, idx_ref, len_ref):
        return (bi, hi // g, sbi, 0)

    def o_map(bi, hi, sbi, ji, idx_ref, len_ref):
        return (bi, hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, bd), q_map),
            pl.BlockSpec((1, 1, 1, bd, seq_blk), k_map),
            pl.BlockSpec((1, 1, seq_blk, dv), v_map),
        ],
        out_specs=pl.BlockSpec((1, 1, dv), o_map),
        scratch_shapes=[
            pltpu.VMEM((1, seq_blk), jnp.float32),   # score accumulator
            pltpu.VMEM((1, 1), jnp.float32),         # running max
            pltpu.VMEM((1, 1), jnp.float32),         # running denom
            pltpu.VMEM((1, dv), jnp.float32),        # output accumulator
        ],
    )
    kernel = functools.partial(_kernel, scale=scale, seq_blk=seq_blk,
                               nb_sel=nb_sel, nsb=nsb)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, dv), v.dtype),
        interpret=interpret,
    )(block_idx, lengths, q_sel, khat_blocks, v)
