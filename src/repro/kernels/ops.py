"""jit'd public wrappers around the Pallas kernels.

``aqua_decode`` takes model-layout tensors (seq-major cache), handles the
dim-major restructuring, padding, query-block gathering and top-k selection,
and dispatches to the kernel. On CPU the kernels run in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import aqua as aqua_lib
from repro.kernels.aqua_decode import aqua_decode_attention
from repro.kernels.flash_attention import flash_attention  # noqa: F401


def to_dim_major_blocks(khat: jax.Array, block_dims: int) -> jax.Array:
    """(B, KV, S, D) seq-major -> (B, KV, NB, bd, S) dim-major blocks.

    In production this is the *storage layout* of the projected key cache
    (written incrementally at insert time); here it is a transpose helper
    for tests/benchmarks entering from the model layout.
    """
    b, kvh, s, d = khat.shape
    assert d % block_dims == 0, (d, block_dims)
    nb = d // block_dims
    kt = khat.transpose(0, 1, 3, 2)                 # (B, KV, D, S)
    return kt.reshape(b, kvh, nb, block_dims, s)


@functools.partial(jax.jit, static_argnames=("k_ratio", "block_dims",
                                             "seq_blk", "interpret"))
def aqua_decode(q_hat: jax.Array, khat: jax.Array, v: jax.Array,
                lengths: jax.Array, *, k_ratio: float = 0.75,
                block_dims: int = 8, seq_blk: int = 128,
                interpret: bool = True) -> jax.Array:
    """End-to-end AQUA decode attention (selection + kernel).

    q_hat: (B, H, D) projected query; khat: (B, KV, S, D) projected key
    cache (seq-major model layout); v: (B, KV, S, Dv); lengths: (B,).
    """
    b, h, d = q_hat.shape
    s = khat.shape[2]
    nb = d // block_dims
    k_dims = max(block_dims, int(round(k_ratio * d)))
    k_dims = ((k_dims + block_dims - 1) // block_dims) * block_dims
    k_dims = min(k_dims, d)

    block_idx = aqua_lib.topk_block_indices(q_hat, k_dims, block_dims)
    # gather the selected q blocks (tiny: H × k elements)
    qb = q_hat.reshape(b, h, nb, block_dims)
    q_sel = jnp.take_along_axis(qb, block_idx[..., None], axis=2)

    pad = (-s) % seq_blk
    if pad:
        khat = jnp.pad(khat, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    khat_blocks = to_dim_major_blocks(khat, block_dims)
    return aqua_decode_attention(q_sel, khat_blocks, v, block_idx, lengths,
                                 block_dims=block_dims, seq_blk=seq_blk,
                                 interpret=interpret)
