"""jit'd public wrappers around the Pallas kernels.

``aqua_decode`` / ``aqua_prefill`` take model-layout tensors (seq-major
cache), handle the dim-major restructuring, padding, query-block gathering
and top-k selection, and dispatch to the kernels. ``interpret=None``
auto-resolves via :mod:`repro.runtime_flags` — compiled on TPU,
interpreted elsewhere — so the same call sites serve production and CI.

Dim-major cache layout contract (shared with ``repro.core.kvcache``):
the projected key cache is stored seq-major ``(B, KV, S, D)`` at the
model layer and viewed dim-major ``(B, KV, NB, bd, S)`` by the kernels,
where ``NB = D // bd`` dim-blocks of ``bd`` sublanes each span the full
lane-dim sequence stripe. Magnitude selection picks whole dim-blocks, so
the kernels stream only the selected ``NB_sel`` stripes HBM→VMEM. The
block-paged cache keeps the same layout *per page* — ``(P, KV, NB, bd,
page_size)`` — and :func:`aqua_paged_decode` threads the per-lane page
table through the kernel's scalar-prefetch ``index_map`` so the physical
page of each sequence block resolves inside the kernel.

Shard-local contract (mesh-native serving): these wrappers are also the
bodies run inside ``shard_map`` by ``repro.core.attention`` — every
shape they see is then *shard-local* (lanes partitioned over the data
axes, KV heads — and their query groups — over ``model``). That works
without changes because nothing here crosses the batch or head axes: the
top-k block-index tables are computed per (row, head), the sequence and
dim axes arrive whole per shard, and the per-shard ``NB_total``/
``NB_sel`` accounting equals the global one (:func:`block_counts`).

The paged wrapper extends the same contract: page-*table* rows are
shard-local (they partition with their lanes over the data axes), while
the page *pool* arrives with its page axis whole on every data shard —
pages are lane-global, any lane may map any physical page, so the
shard-local table entries are pool-global page ids that dereference
unchanged inside the kernel's ``index_map``. Only the pool's KV-head
axis is shard-local (partitioned over ``model``, whole dim-blocks and
whole pages riding with their head); no collective is ever needed
between the table lookup and the page DMA.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import aqua as aqua_lib
from repro.core.aqua import ceil_to as _ceil_to
from repro.kernels.aqua_decode import (aqua_decode_attention,
                                       aqua_paged_decode_attention)
from repro.kernels.aqua_prefill import aqua_prefill_attention
from repro.kernels.flash_attention import flash_attention  # noqa: F401


def to_dim_major_blocks(khat: jax.Array, block_dims: int) -> jax.Array:
    """(B, KV, S, D) seq-major -> (B, KV, NB, bd, S) dim-major blocks.

    In production this is the *storage layout* of the projected key cache
    (written incrementally at insert time); here it is a transpose helper
    for tests/benchmarks entering from the model layout.
    """
    b, kvh, s, d = khat.shape
    assert d % block_dims == 0, (d, block_dims)
    nb = d // block_dims
    kt = khat.transpose(0, 1, 3, 2)                 # (B, KV, D, S)
    return kt.reshape(b, kvh, nb, block_dims, s)


def round_k_dims(d: int, k_ratio: float, block_dims: int) -> int:
    """Kept-dim count for a k_ratio: rounded to the nearest dim count, then
    up to a whole number of dim-blocks, clamped to [block_dims, d]. The
    single source of truth shared by the kernel wrappers, oracles and
    benchmarks."""
    k_dims = max(block_dims, int(round(k_ratio * d)))
    k_dims = ((k_dims + block_dims - 1) // block_dims) * block_dims
    return min(k_dims, d)


def block_counts(d: int, k_ratio: float, block_dims: int) -> tuple:
    """(NB_total, NB_sel) dim-block accounting for head dim ``d``.

    Shard-local and global accounting coincide under the serving mesh:
    ``shard_map`` partitions lanes and KV heads, never the dim axis, so
    every shard holds all ``NB_total`` dim-blocks of its heads' K̂ stripes
    and selects the same ``NB_sel`` of them. Used by the benchmarks'
    HBM-byte ratios so they stay honest for the mesh rows too."""
    return d // block_dims, round_k_dims(d, k_ratio, block_dims) // block_dims


@functools.partial(jax.jit, static_argnames=("k_ratio", "block_dims",
                                             "seq_blk", "scale",
                                             "interpret"))
def aqua_decode(q_hat: jax.Array, khat: jax.Array, v: jax.Array,
                lengths: jax.Array, *, k_ratio: float = 0.75,
                block_dims: int = 8, seq_blk: int = 128,
                scale: Optional[float] = None,
                interpret: Optional[bool] = None) -> jax.Array:
    """End-to-end AQUA decode attention (selection + kernel).

    q_hat: (B, H, D) projected query; khat: (B, KV, S, D) projected key
    cache (seq-major model layout); v: (B, KV, S, Dv); lengths: (B,).
    """
    b, h, d = q_hat.shape
    s = khat.shape[2]
    nb = d // block_dims
    k_dims = round_k_dims(d, k_ratio, block_dims)

    block_idx = aqua_lib.topk_block_indices(q_hat, k_dims, block_dims)
    # gather the selected q blocks (tiny: H × k elements)
    qb = q_hat.reshape(b, h, nb, block_dims)
    q_sel = jnp.take_along_axis(qb, block_idx[..., None], axis=2)

    pad = (-s) % seq_blk
    if pad:
        khat = jnp.pad(khat, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    khat_blocks = to_dim_major_blocks(khat, block_dims)
    return aqua_decode_attention(q_sel, khat_blocks, v, block_idx, lengths,
                                 block_dims=block_dims, seq_blk=seq_blk,
                                 scale=scale, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k_ratio", "block_dims",
                                             "seq_blk", "scale",
                                             "interpret"))
def aqua_paged_decode(q_hat: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                      page_table: jax.Array, lengths: jax.Array,
                      k_scale: Optional[jax.Array] = None,
                      v_scale: Optional[jax.Array] = None,
                      part_idx: Optional[jax.Array] = None,
                      block_idx: Optional[jax.Array] = None, *,
                      k_ratio: float = 0.75, block_dims: int = 8,
                      seq_blk: int = 128, scale: Optional[float] = None,
                      interpret: Optional[bool] = None) -> jax.Array:
    """End-to-end AQUA decode attention over a *paged* KV pool.

    q_hat: (B, H, D) projected query; k_pool: (P, KV, ps, D) projected key
    page pool (seq-major per page); v_pool: (P, KV, ps, Dv);
    page_table: (B, NP_lane) int32 (-1 unmapped); lengths: (B,).
    k_scale/v_scale: (P, SH) f32 per-page scales when the pools are int8
    quantized (None for full precision) — threaded to the kernel as extra
    scalar-prefetch operands, where the key scale folds into the softmax
    scale (dequant-free score accumulation).
    part_idx: (B, KP) int32 stage-1 participating logical pages per lane
    (``core.selection.participating_pages``), or None for all pages —
    hierarchical AQUA's token-sparsity table, also scalar-prefetched;
    the kernel walks only those KP pages. block_idx: precomputed (B, H,
    NB_sel) stage-2 dim-block selection (a ``SelectionPlan``'s), or None
    to select here from ``q_hat`` magnitudes.

    Same magnitude selection as :func:`aqua_decode`; the physical page of
    each sequence block is resolved inside the kernel's scalar-prefetch
    ``index_map`` from the page table — no gathered contiguous view is
    ever materialized. ``seq_blk`` is clamped to the page size (a sequence
    block never spans pages); non-divisible remainders fall back to one
    block per page.
    """
    b, h, d = q_hat.shape
    ps = k_pool.shape[2]
    nb = d // block_dims
    k_dims = round_k_dims(d, k_ratio, block_dims)

    if block_idx is None:
        block_idx = aqua_lib.topk_block_indices(q_hat, k_dims, block_dims)
    qb = q_hat.reshape(b, h, nb, block_dims)
    q_sel = jnp.take_along_axis(qb, block_idx[..., None], axis=2)

    seq_blk = min(seq_blk, ps)
    if ps % seq_blk != 0:
        seq_blk = ps
    khat_pages = to_dim_major_blocks(k_pool, block_dims)  # (P,KV,NB,bd,ps)
    return aqua_paged_decode_attention(q_sel, khat_pages, v_pool, block_idx,
                                       page_table, lengths,
                                       k_scale, v_scale, part_idx,
                                       block_dims=block_dims,
                                       seq_blk=seq_blk, scale=scale,
                                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k_ratio", "block_dims",
                                             "q_blk", "k_blk", "causal",
                                             "window", "scale", "interpret"))
def aqua_prefill(q_hat: jax.Array, khat: jax.Array, v: jax.Array,
                 lengths: Optional[jax.Array] = None, *,
                 k_ratio: float = 0.75, block_dims: int = 8,
                 q_blk: int = 128, k_blk: int = 128, causal: bool = True,
                 window: Optional[int] = None,
                 scale: Optional[float] = None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """End-to-end AQUA block-sparse chunked-prefill attention.

    Queries are processed in seq-chunks of ``q_blk``; each chunk shares the
    dim-block set selected from its aggregated |q̂| magnitudes (see
    :func:`repro.core.aqua.chunk_topk_block_indices`), so only
    ``k_ratio`` of the dim-major key stripes are streamed per tile. The
    masked-dense oracle is :func:`repro.kernels.ref.aqua_prefill_ref`.

    q_hat: (B, H, S, D) projected queries (head-major kernel layout);
    khat: (B, KV, S, D) projected keys (seq-major); v: (B, KV, S, Dv);
    lengths: (B,) valid lengths (None -> all rows full). Returns
    (B, H, S, Dv); rows at/beyond a row's length are don't-care.
    """
    b, h, s, d = q_hat.shape
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)

    # clamp chunk sizes for short sequences, then pad S so both divide it
    q_blk = min(q_blk, _ceil_to(s, 8))
    k_blk = min(k_blk, _ceil_to(s, 8))
    spad = _ceil_to(s, math.lcm(q_blk, k_blk))
    pad = spad - s
    if pad:
        q_hat = jnp.pad(q_hat, ((0, 0), (0, 0), (0, pad), (0, 0)))
        khat = jnp.pad(khat, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nqc = spad // q_blk
    nb = d // block_dims
    k_dims = round_k_dims(d, k_ratio, block_dims)

    block_idx = aqua_lib.chunk_topk_block_indices(q_hat, k_dims, block_dims,
                                                  q_blk, lengths)
    # gather selected q dim-blocks per chunk: (B,H,NQC,NB_sel,q_blk,bd)
    qb = q_hat.reshape(b, h, nqc, q_blk, nb, block_dims
                       ).transpose(0, 1, 2, 4, 3, 5)
    q_sel = jnp.take_along_axis(qb, block_idx[..., None, None], axis=3)

    khat_blocks = to_dim_major_blocks(khat, block_dims)
    out = aqua_prefill_attention(q_sel, khat_blocks, v, block_idx, lengths,
                                 block_dims=block_dims, q_blk=q_blk,
                                 k_blk=k_blk, causal=causal, window=window,
                                 scale=scale, interpret=interpret)
    return out[:, :, :s]


@functools.partial(jax.jit, static_argnames=("q_offset", "k_ratio",
                                             "block_dims", "q_blk", "k_blk",
                                             "causal", "window", "scale",
                                             "interpret"))
def aqua_prefill_chunk(q_hat: jax.Array, khat: jax.Array, v: jax.Array,
                       lengths: jax.Array, *, q_offset: int,
                       mag_state: Optional[jax.Array] = None,
                       k_ratio: float = 0.75, block_dims: int = 8,
                       q_blk: int = 128, k_blk: int = 128,
                       causal: bool = True, window: Optional[int] = None,
                       scale: Optional[float] = None,
                       interpret: Optional[bool] = None) -> tuple:
    """Chunk-resumable AQUA prefill: attention for query rows
    [q_offset, q_offset + T) against the key stripe [0, S).

    Masked-out key tiles are exact no-ops in the online softmax, so when
    every chunk boundary is a ``q_blk`` multiple the concatenated chunk
    outputs are **bitwise identical** to one monolithic
    :func:`aqua_prefill` call — each chunk runs the same tiles with the
    same dim-block selection. A ragged boundary (``q_offset % q_blk !=
    0``) is still numerically valid (tiles re-anchor at ``q_offset``) but
    only approximately equal, because the straddling tile aggregates |q̂|
    over a different row set; ``mag_state`` keeps the selection itself
    consistent across a ragged split.

    q_hat:     (B, H, T, D) projected queries for this chunk only
    khat:      (B, KV, S, D) projected keys, seq-major, covering at least
               rows [0, q_offset + T) — typically the whole cache stripe
    v:         (B, KV, S, Dv)
    lengths:   (B,) — valid *sequence* lengths (global positions; both the
               key mask and the |q̂| aggregation use them)
    q_offset:  static global row index of this chunk's first query
    mag_state: (B, H, NB_total) float32 running |q̂| block aggregate of a
               partially filled leading tile (from the previous chunk's
               carry), or None. Added to this chunk's first tile before
               selection.
    returns:   (out (B, H, T, Dv), carry (B, H, NB_total) float32) —
               ``carry`` is the trailing tile's |q̂| aggregate when
               ``T % q_blk != 0`` (feed it to the next chunk's
               ``mag_state``), else zeros.
    """
    b, h, t, d = q_hat.shape
    s = khat.shape[2]
    assert q_offset >= 0 and q_offset + t <= s, (q_offset, t, s)

    q_blk = min(q_blk, _ceil_to(t, 8))
    k_blk = min(k_blk, _ceil_to(s, 8))
    tpad = _ceil_to(t, q_blk)
    spad = _ceil_to(max(s, q_offset + tpad), k_blk)
    if tpad - t:
        q_hat = jnp.pad(q_hat, ((0, 0), (0, 0), (0, tpad - t), (0, 0)))
    if spad - s:
        khat = jnp.pad(khat, ((0, 0), (0, 0), (0, spad - s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, spad - s), (0, 0)))
    nqc = tpad // q_blk
    nb = d // block_dims
    k_dims = round_k_dims(d, k_ratio, block_dims)
    kb = k_dims // block_dims

    # chunk-local |q̂| block aggregation — same math as
    # chunk_topk_block_indices but masked by *global* positions and
    # carrying the previous chunk's partial leading-tile aggregate
    mag = jnp.abs(q_hat.astype(jnp.float32))
    row = jnp.arange(tpad)
    valid = (row[None, :] < t) & (q_offset + row[None, :] < lengths[:, None])
    mag = mag * valid[:, None, :, None]
    bmag = mag.reshape(b, h, nqc, q_blk, nb, block_dims
                       ).sum(axis=(3, 5))                    # (B,H,NQC,NB)
    if mag_state is not None:
        bmag = bmag.at[:, :, 0, :].add(mag_state)
    if t % q_blk != 0:
        carry = bmag[:, :, -1, :]
    else:
        carry = jnp.zeros((b, h, nb), jnp.float32)
    _, bidx = jax.lax.top_k(bmag, kb)
    block_idx = jnp.sort(bidx, axis=-1).astype(jnp.int32)

    qb = q_hat.reshape(b, h, nqc, q_blk, nb, block_dims
                       ).transpose(0, 1, 2, 4, 3, 5)
    q_sel = jnp.take_along_axis(qb, block_idx[..., None, None], axis=3)

    khat_blocks = to_dim_major_blocks(khat, block_dims)
    out = aqua_prefill_attention(q_sel, khat_blocks, v, block_idx, lengths,
                                 block_dims=block_dims, q_blk=q_blk,
                                 k_blk=k_blk, causal=causal, window=window,
                                 scale=scale, interpret=interpret,
                                 q_offset=q_offset)
    return out[:, :, :t], carry
