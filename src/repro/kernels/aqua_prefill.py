"""AQUA block-sparse chunked-prefill Pallas TPU kernel.

Prefill counterpart of ``aqua_decode.py``: the projected key cache keeps the
same **dim-major** layout ``(B, KV, NB_total, bd, S)`` — dim-blocks of ``bd``
sublanes × a long lane-dim sequence stripe — and the magnitude-selected
dim-block indices are scalar-prefetched and dereferenced inside the K
BlockSpec ``index_map``. Queries are processed in causal seq-chunks of
``q_blk``: each chunk aggregates |q̂| per dim-block over its queries and the
top ``NB_sel`` blocks are shared by the whole chunk (the chunked
generalization of the paper's per-query selection; equal to it at
``q_blk=1``). Only ``NB_sel / NB_total = k_ratio`` of the key dim-blocks are
streamed HBM→VMEM per (query-chunk, key-chunk) tile, so the quadratic
score-read term — the cost the paper targets — drops to ``k_ratio`` of
dense flash attention.

The value product and online softmax are fused flash style; the (S, S)
score matrix never materializes in HBM. Causally dead (query-chunk,
key-chunk) tiles are skipped via ``pl.when`` so their partial dot products
cost nothing.

Grid: (B, H, num_q_chunks, num_k_chunks, NB_sel) — dim-block index j
innermost; the V block index_map is constant in j, so Pallas keeps the V
tile resident across the j loop (single fetch per key chunk).

Mesh-native serving runs this kernel *inside* ``shard_map``
(``repro.core.attention.shard_mapped_prefill_kernel``): B and H are then
shard-local extents (lanes over the data axes, KV heads + their query
groups over ``model``), while S and the dim-block axis arrive whole per
shard — each model shard streams whole dim-blocks of its own heads and
``NB_sel``/``NB_total`` are the same per shard as globally.

Paged serving contract: prefill attention itself reads only the prompt's
own q̂/K̂/V (never the pool), so this kernel runs unchanged for paged
admissions — the *writes* land in pool pages afterwards
(``kvcache.paged_graft`` scatters the B=1 prefill cache through the
lane's page table, ``kvcache.paged_write_tail`` the prefix-shared tail).
Only the decode kernel walks the page table at read time
(``aqua_decode.aqua_paged_decode_attention``), because only decode reads
a paged cache inside the hot loop; prefix-shared *tail* prefills read the
shared pages through the gathered lane view on the reference path
(admission-time, off the steady-state roofline).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import runtime_flags as _rtf

NEG_INF = -1e30


def _kernel(idx_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            s_ref, m_ref, l_ref, acc_ref, *, scale: float, q_blk: int,
            k_blk: int, nb_sel: int, nkc: int, causal: bool,
            window: Optional[int], q_offset: int):
    bi = pl.program_id(0)
    qc = pl.program_id(2)
    kc = pl.program_id(3)
    j = pl.program_id(4)

    @pl.when((kc == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Skip tiles that the causal / window band fully masks: the last query
    # of this chunk sits before the first key, or every key is staler than
    # the window of the first query. ``q_offset`` shifts query positions
    # for chunk-resumable invocations (queries are rows
    # [q_offset, q_offset + T) of the sequence whose keys span the stripe).
    live = kc >= 0
    if causal:
        live &= kc * k_blk <= q_offset + qc * q_blk + (q_blk - 1)
    if window is not None:
        live &= kc * k_blk + (k_blk - 1) > q_offset + qc * q_blk - window

    @pl.when(live & (j == 0))
    def _reset_scores():
        s_ref[...] = jnp.zeros_like(s_ref)

    @pl.when(live)
    def _accumulate():
        # partial scores for this selected dim-block:
        # (q_blk, bd) @ (bd, k_blk)
        q_blkj = q_ref[0, 0, 0, 0].astype(jnp.float32)
        k_blkj = k_ref[0, 0, 0].astype(jnp.float32)
        s_ref[...] += jax.lax.dot_general(
            q_blkj, k_blkj, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(live & (j == nb_sel - 1))
    def _finalize_tile():
        s = s_ref[...] * scale                       # (q_blk, k_blk)
        qpos = q_offset + qc * q_blk + jax.lax.broadcasted_iota(
            jnp.int32, (q_blk, k_blk), 0)
        kpos = kc * k_blk + jax.lax.broadcasted_iota(
            jnp.int32, (q_blk, k_blk), 1)
        mask = kpos < len_ref[bi]
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                          # (q_blk, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        v_blk = v_ref[0, 0].astype(jnp.float32)      # (k_blk, Dv)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when((kc == nkc - 1) & (j == nb_sel - 1))
    def _write():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)
                      )[None, None].astype(o_ref.dtype)


def _part_kernel(idx_ref, part_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                 s_ref, m_ref, l_ref, acc_ref, *, scale: float, q_blk: int,
                 k_blk: int, nb_sel: int, kt: int, causal: bool,
                 window: Optional[int], q_offset: int):
    """Hierarchical twin of :func:`_kernel` — the grid's key-chunk axis
    runs over *participating* k-tiles only; ``part_ref`` (B, NQC, KT)
    maps each grid step to its logical key chunk (per q-tile, sorted
    ascending, diagonal tiles pinned —
    ``core.selection.chunk_participating_tiles``). Dropped tiles' K̂/V
    bytes are never streamed. Causal/window masking uses the logical
    chunk, so the math on surviving tiles is identical to :func:`_kernel`
    visiting the same tiles."""
    bi = pl.program_id(0)
    qc = pl.program_id(2)
    kci = pl.program_id(3)
    j = pl.program_id(4)
    kc = part_ref[bi, qc, kci]                       # logical key chunk

    @pl.when((kci == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = kc >= 0
    if causal:
        live &= kc * k_blk <= q_offset + qc * q_blk + (q_blk - 1)
    if window is not None:
        live &= kc * k_blk + (k_blk - 1) > q_offset + qc * q_blk - window

    @pl.when(live & (j == 0))
    def _reset_scores():
        s_ref[...] = jnp.zeros_like(s_ref)

    @pl.when(live)
    def _accumulate():
        q_blkj = q_ref[0, 0, 0, 0].astype(jnp.float32)
        k_blkj = k_ref[0, 0, 0].astype(jnp.float32)
        s_ref[...] += jax.lax.dot_general(
            q_blkj, k_blkj, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(live & (j == nb_sel - 1))
    def _finalize_tile():
        s = s_ref[...] * scale                       # (q_blk, k_blk)
        qpos = q_offset + qc * q_blk + jax.lax.broadcasted_iota(
            jnp.int32, (q_blk, k_blk), 0)
        kpos = kc * k_blk + jax.lax.broadcasted_iota(
            jnp.int32, (q_blk, k_blk), 1)
        mask = kpos < len_ref[bi]
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                          # (q_blk, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        v_blk = v_ref[0, 0].astype(jnp.float32)      # (k_blk, Dv)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when((kci == kt - 1) & (j == nb_sel - 1))
    def _write():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)
                      )[None, None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_dims", "q_blk", "k_blk",
                                             "causal", "window", "scale",
                                             "interpret", "q_offset"))
def aqua_prefill_attention(q_sel: jax.Array, khat_blocks: jax.Array,
                           v: jax.Array, block_idx: jax.Array,
                           lengths: jax.Array,
                           kc_part: Optional[jax.Array] = None,
                           *, block_dims: int = 8,
                           q_blk: int = 128, k_blk: int = 128,
                           causal: bool = True,
                           window: Optional[int] = None,
                           scale: Optional[float] = None,
                           interpret: Optional[bool] = None,
                           q_offset: int = 0) -> jax.Array:
    """Block-sparse AQUA chunked-prefill attention.

    q_sel:       (B, H, NQC, NB_sel, q_blk, bd) — queries, pre-gathered
                 selected dim-blocks per causal query chunk
    khat_blocks: (B, KV, NB_total, bd, S) — dim-major projected key cache
    v:           (B, KV, S, Dv)
    block_idx:   (B, H, NQC, NB_sel) int32 — selected dim-block ids (sorted)
    lengths:     (B,) int32 — valid sequence length per row (keys beyond are
                 masked; query rows beyond produce don't-care output)
    kc_part:     (B, NQC, KT) int32 — per-q-tile *participating* key-chunk
                 indices (sorted ascending, diagonal pinned —
                 ``core.selection.chunk_participating_tiles``), or None to
                 visit every key chunk. When given, the grid's key-chunk
                 extent shrinks from NKC to KT; dropped tiles' key/value
                 bytes are never streamed (hierarchical AQUA's q-tile
                 token-sparsity stage for chunked long prefills).
    scale:       score scale; default 1/sqrt(NB_total * bd). AQUA
                 approximates *full* head-dim scores, so pass
                 1/sqrt(head_dim) when k̂ is statically sliced.
    q_offset:    static row offset of the queries within the key stripe —
                 the chunk-resumable entry (``ops.aqua_prefill_chunk``):
                 the queries are sequence rows [q_offset, q_offset + T)
                 while the keys span [0, S). Masked-out key tiles are
                 exact no-ops in the online softmax, so a q_blk-aligned
                 chunk invocation is bitwise identical to the matching
                 tiles of the monolithic call. 0 = classic full prefill.
    returns out: (B, H, T, Dv) with T = NQC * q_blk
    """
    b, h, nqc, nb_sel, qb, bd = q_sel.shape
    _, kvh, nb_total, bd2, s = khat_blocks.shape
    assert bd == bd2 == block_dims and qb == q_blk
    dv = v.shape[-1]
    g = h // kvh
    assert s % k_blk == 0, (s, k_blk)
    assert q_offset >= 0 and q_offset + nqc * q_blk <= s, \
        (q_offset, nqc, q_blk, s)
    nkc = s // k_blk
    if scale is None:
        scale = 1.0 / ((nb_total * bd) ** 0.5)
    interpret = _rtf.resolve_interpret(interpret)

    hier = kc_part is not None
    kt = kc_part.shape[2] if hier else nkc
    grid = (b, h, nqc, kt, nb_sel)

    if hier:
        # key-chunk axis walks participating tiles only: grid step kci ->
        # logical chunk kc_part[bi, qi, kci] (scalar-prefetch operand 1).
        def q_map(bi, hi, qi, ki, ji, *refs):
            return (bi, hi, qi, ji, 0, 0)

        def k_map(bi, hi, qi, ki, ji, *refs):
            return (bi, hi // g, refs[0][bi, hi, qi, ji], 0,
                    refs[1][bi, qi, ki])

        def v_map(bi, hi, qi, ki, ji, *refs):
            return (bi, hi // g, refs[1][bi, qi, ki], 0)

        def o_map(bi, hi, qi, ki, ji, *refs):
            return (bi, hi, qi, 0)

        nsp = 3
        kernel = functools.partial(_part_kernel, scale=scale, q_blk=q_blk,
                                   k_blk=k_blk, nb_sel=nb_sel, kt=kt,
                                   causal=causal, window=window,
                                   q_offset=q_offset)
        prefetch = (block_idx, kc_part, lengths)
    else:
        def q_map(bi, hi, qi, ki, ji, idx_ref, len_ref):
            return (bi, hi, qi, ji, 0, 0)

        def k_map(bi, hi, qi, ki, ji, idx_ref, len_ref):
            return (bi, hi // g, idx_ref[bi, hi, qi, ji], 0, ki)

        def v_map(bi, hi, qi, ki, ji, idx_ref, len_ref):
            return (bi, hi // g, ki, 0)

        def o_map(bi, hi, qi, ki, ji, idx_ref, len_ref):
            return (bi, hi, qi, 0)

        nsp = 2
        kernel = functools.partial(_kernel, scale=scale, q_blk=q_blk,
                                   k_blk=k_blk, nb_sel=nb_sel, nkc=nkc,
                                   causal=causal, window=window,
                                   q_offset=q_offset)
        prefetch = (block_idx, lengths)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=nsp,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, 1, q_blk, bd), q_map),
            pl.BlockSpec((1, 1, 1, bd, k_blk), k_map),
            pl.BlockSpec((1, 1, k_blk, dv), v_map),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, dv), o_map),
        scratch_shapes=[
            pltpu.VMEM((q_blk, k_blk), jnp.float32),  # score accumulator
            pltpu.VMEM((q_blk, 1), jnp.float32),      # running max
            pltpu.VMEM((q_blk, 1), jnp.float32),      # running denom
            pltpu.VMEM((q_blk, dv), jnp.float32),     # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, nqc * q_blk, dv), v.dtype),
        interpret=interpret,
    )(*prefetch, q_sel, khat_blocks, v)
