"""Causal flash-attention Pallas TPU kernel (prefill path).

Standard memory-efficient attention with online softmax; supports GQA
(kv head = query head // group) and sliding windows. Used by the prefill
benchmarks; AQUA prefill masking happens on the query side *before* this
kernel (masked-q identity, DESIGN.md §2), so the same kernel serves both.

Grid: (B, H, num_q_blocks, num_k_blocks), k innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, q_blk: int, k_blk: int, nkb: int,
            causal: bool, window):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)      # (q_blk, D)
    k = k_ref[0, 0].astype(jnp.float32)      # (k_blk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qb * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, k_blk), 0)
    kpos = kb * k_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, k_blk), 1)
    mask = jnp.ones((q_blk, k_blk), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                       # (q_blk, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    v_blk = v_ref[0, 0].astype(jnp.float32)   # (k_blk, D)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v_blk, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == nkb - 1)
    def _write():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30))[None, None].astype(
                          o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_blk",
                                             "k_blk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window=None, q_blk: int = 128,
                    k_blk: int = 128, interpret=None) -> jax.Array:
    """q: (B, H, S, D); k, v: (B, KV, S, D). Returns (B, H, S, D).

    ``interpret=None`` resolves via runtime_flags: compiled on TPU,
    interpreted elsewhere.
    """
    from repro import runtime_flags as _rtf
    interpret = _rtf.resolve_interpret(interpret)
    b, h, s, d = q.shape
    kvh = k.shape[1]
    g = h // kvh
    assert s % q_blk == 0 and s % k_blk == 0, (s, q_blk, k_blk)
    nqb, nkb = s // q_blk, s // k_blk
    scale = 1.0 / (d ** 0.5)
    grid = (b, h, nqb, nkb)

    kernel = functools.partial(_kernel, scale=scale, q_blk=q_blk, k_blk=k_blk,
                               nkb=nkb, causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, d), lambda bi, hi, qb, kb: (bi, hi, qb, 0)),
            pl.BlockSpec((1, 1, k_blk, d),
                         lambda bi, hi, qb, kb, g=g: (bi, hi // g, kb, 0)),
            pl.BlockSpec((1, 1, k_blk, d),
                         lambda bi, hi, qb, kb, g=g: (bi, hi // g, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, d),
                               lambda bi, hi, qb, kb: (bi, hi, qb, 0)),
        scratch_shapes=[
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, d), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), v.dtype),
        interpret=interpret,
    )(q, k, v)
