"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def aqua_decode_ref(q_hat: jax.Array, khat: jax.Array, v: jax.Array,
                    block_idx: jax.Array, lengths: jax.Array,
                    block_dims: int) -> jax.Array:
    """Masked-dense oracle for the block-sparse decode kernel.

    q_hat: (B, H, D); khat: (B, KV, S, D) (seq-major); v: (B, KV, S, Dv);
    block_idx: (B, H, NB_sel); lengths: (B,). Returns (B, H, Dv).
    """
    b, h, d = q_hat.shape
    kvh, s = khat.shape[1], khat.shape[2]
    g = h // kvh
    nb = d // block_dims
    # build the 0/1 dim mask from the selected block ids
    sel = jax.nn.one_hot(block_idx, nb, dtype=jnp.float32).sum(2)  # (B,H,NB)
    mask = jnp.repeat(sel, block_dims, axis=-1)                    # (B,H,D)
    qm = (q_hat.astype(jnp.float32) * mask).reshape(b, kvh, g, d)
    scores = jnp.einsum("bkgd,bksd->bkgs", qm,
                        khat.astype(jnp.float32)) / (d ** 0.5)
    valid = jnp.arange(s)[None, :] < lengths[:, None]              # (B,S)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(b, h, -1).astype(v.dtype)


def aqua_prefill_ref(q_hat: jax.Array, khat: jax.Array, v: jax.Array,
                     block_idx: jax.Array, lengths: jax.Array,
                     block_dims: int, q_chunk: int, *, causal: bool = True,
                     window: Optional[int] = None,
                     scale: Optional[float] = None) -> jax.Array:
    """Masked-dense oracle for the block-sparse chunked-prefill kernel.

    Every query in a chunk shares the chunk's selected dim-block set
    (masked-q identity: zeroing unselected q̂ dims equals not streaming the
    matching K̂ dim-blocks).

    q_hat: (B, H, S, D); khat: (B, KV, S, D) seq-major; v: (B, KV, S, Dv);
    block_idx: (B, H, S // q_chunk, NB_sel); lengths: (B,).
    Returns (B, H, S, Dv).
    """
    b, h, s, d = q_hat.shape
    kvh = khat.shape[1]
    g = h // kvh
    nb = d // block_dims
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    sel = jax.nn.one_hot(block_idx, nb, dtype=jnp.float32).sum(3)  # B,H,NQC,NB
    mask = jnp.repeat(jnp.minimum(sel, 1.0), block_dims, axis=-1)  # ...,D
    mask = jnp.repeat(mask, q_chunk, axis=2)                       # B,H,S,D
    qm = (q_hat.astype(jnp.float32) * mask).reshape(b, kvh, g, s, d)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qm,
                        khat.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    m = jnp.broadcast_to(kpos < lengths[:, None, None, None, None],
                         (b, 1, 1, s, s))
    if causal:
        m = m & (qpos >= kpos)
    if window is not None:
        m = m & (kpos > qpos - window)
    scores = jnp.where(m, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", w, v.astype(jnp.float32))
    return out.reshape(b, h, s, -1).astype(v.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q: (B,H,S,D); k, v: (B,KV,S,D). Returns (B,H,S,D)."""
    b, h, s, d = q.shape
    kvh = k.shape[1]
    g = h // kvh
    qr = q.reshape(b, kvh, g, s, d).astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qr,
                        k.astype(jnp.float32)) / (d ** 0.5)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", w, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(v.dtype)
