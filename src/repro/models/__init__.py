"""Model factory."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.base import LM, DecodeState  # noqa: F401


def build_model(cfg: ModelConfig) -> LM:
    if cfg.family in ("dense", "vlm", "moe"):
        from repro.models.transformer import DenseLM
        return DenseLM(cfg)
    if cfg.family == "encdec":
        from repro.models.transformer import EncDecLM
        return EncDecLM(cfg)
    if cfg.family == "ssm":
        from repro.models.mamba2 import Mamba2LM
        return Mamba2LM(cfg)
    if cfg.family == "hybrid":
        from repro.models.rglru import HybridLM
        return HybridLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
