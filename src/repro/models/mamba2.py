"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060), chunked matmul
form: intra-chunk attention-like blocks on the MXU + inter-chunk associative
scan. Attention-free — AQUA is inapplicable here (DESIGN.md §4); decode uses
O(1) state instead of a KV cache, which is why this arch runs the
``long_500k`` cell.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from repro import runtime_flags as _rtf


def _scan(*args, **kw):
    kw.update(_rtf.scan_kwargs())
    return jax.lax.scan(*args, **kw)


from repro.configs.base import ModelConfig
from repro.core.kvcache import SSMCache
from repro.models import layers as L
from repro.models.base import LM, DecodeState


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., l) -> (..., l, l); out[i, j] = sum a[j+1..i] for i >= j."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, d_skip, chunk: int):
    """SSD forward (no initial state).

    x:  (B, S, H, P)   dt: (B, S, H)   a_log: (H,) (negative decay)
    b, c: (B, S, G, N) with G groups broadcast over heads.
    Returns y: (B, S, H, P) and final state (B, H, P, N).
    """
    bsz, s0, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    # pad to a chunk multiple; dt=0 on padding -> decay 1, contribution 0,
    # so states and real outputs are unaffected.
    s = ((s0 + chunk - 1) // chunk) * chunk
    if s != s0:
        padw = ((0, 0), (0, s - s0), (0, 0), (0, 0))
        x = jnp.pad(x, padw)
        b = jnp.pad(b, padw)
        c = jnp.pad(c, padw)
        dt = jnp.pad(dt, ((0, 0), (0, s - s0), (0, 0)))
    nc = s // chunk
    rep = h // g
    bh = jnp.repeat(b, rep, axis=2)  # (B,S,H,N)
    ch = jnp.repeat(c, rep, axis=2)

    xd = (x * dt[..., None]).reshape(bsz, nc, chunk, h, p)
    a = (dt * a_log[None, None, :]).reshape(bsz, nc, chunk, h)  # log decay
    bh = bh.reshape(bsz, nc, chunk, h, n)
    ch = ch.reshape(bsz, nc, chunk, h, n)

    a_t = a.transpose(0, 1, 3, 2)          # (B,C,H,L)
    a_cum = jnp.cumsum(a_t, axis=-1)       # (B,C,H,L)

    # 1. intra-chunk (diagonal blocks)
    lmat = jnp.exp(_segsum(a_t))           # (B,C,H,L,L)
    y_diag = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp", ch, bh, lmat, xd)

    # 2. chunk-final states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)       # (B,C,H,L)
    states = jnp.einsum("bclhn,bchl,bclhp->bchpn", bh, decay_states, xd)

    # 3. inter-chunk recurrence via associative scan
    chunk_decay = jnp.exp(a_cum[..., -1])  # (B,C,H)

    def combine(e1, e2):
        d1, s1 = e1
        d2, s2 = e2
        return d1 * d2, s1 * d2[..., None, None] + s2
    dec_all, st_all = jax.lax.associative_scan(
        combine, (chunk_decay, states), axis=1)
    final_state = st_all[:, -1]
    # state entering chunk c = scanned value at c-1 (zeros for c=0)
    h_in = jnp.concatenate(
        [jnp.zeros_like(st_all[:, :1]), st_all[:, :-1]], axis=1)

    # 4. off-diagonal contribution
    out_decay = jnp.exp(a_cum).transpose(0, 1, 3, 2)       # (B,C,L,H)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", ch, h_in, out_decay)

    y = (y_diag + y_off).reshape(bsz, s, h, p)[:, :s0]
    y = y + x[:, :s0] * d_skip[None, None, :, None]
    return y, final_state


def ssd_step(state, x_t, dt_t, a_log, b_t, c_t, d_skip):
    """One decode step. state: (B,H,P,N); x_t: (B,H,P); dt_t: (B,H);
    b_t, c_t: (B,G,N). Returns (y_t, new_state)."""
    h = x_t.shape[1]
    g = b_t.shape[1]
    rep = h // g
    bh = jnp.repeat(b_t, rep, axis=1)  # (B,H,N)
    ch = jnp.repeat(c_t, rep, axis=1)
    da = jnp.exp(dt_t * a_log[None, :])                    # (B,H)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt_t, x_t, bh)
    state = state * da[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, ch)
    return y + x_t * d_skip[None, :, None], state


class Mamba2LM(LM):
    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        s = cfg.ssm
        self.d_inner = s.expand * cfg.d_model
        self.nheads = self.d_inner // s.head_dim
        self.conv_channels = self.d_inner + 2 * s.ngroups * s.state_dim

    def _init_block(self, rng, dtype):
        cfg, s = self.cfg, self.cfg.ssm
        di, nh, cc = self.d_inner, self.nheads, self.conv_channels
        ks = jax.random.split(rng, 4)
        proj_out = 2 * di + 2 * s.ngroups * s.state_dim + nh
        return {
            "ln": jnp.ones((cfg.d_model,), dtype),
            "in_proj": jax.random.normal(
                ks[0], (cfg.d_model, proj_out), dtype) * cfg.d_model ** -0.5,
            "conv_w": jax.random.normal(ks[1], (s.conv_width, cc), dtype)
            * s.conv_width ** -0.5,
            "conv_b": jnp.zeros((cc,), dtype),
            "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
            "dt_bias": jnp.zeros((nh,), jnp.float32),
            "d_skip": jnp.ones((nh,), jnp.float32),
            "out_norm": jnp.ones((di,), dtype),
            "out_proj": jax.random.normal(
                ks[2], (di, cfg.d_model), dtype) * di ** -0.5,
        }

    def init(self, rng: jax.Array):
        cfg, dt = self.cfg, self.param_dtype
        k1, k2 = jax.random.split(rng)
        rngs = jax.random.split(k2, cfg.num_layers)
        return {
            "embed": L.init_embedding(k1, cfg.vocab_size, cfg.d_model, dt),
            "layers": jax.vmap(lambda r: self._init_block(r, dt))(rngs),
            "ln_f": jnp.ones((cfg.d_model,), dt),
        }

    def _split(self, zxbcdt):
        s, di, nh = self.cfg.ssm, self.d_inner, self.nheads
        gn = s.ngroups * s.state_dim
        z = zxbcdt[..., :di]
        xbc = zxbcdt[..., di:di + di + 2 * gn]
        dt = zxbcdt[..., di + di + 2 * gn:]
        return z, xbc, dt

    def _block_seq(self, p, x):
        """Full-sequence block: x (B,S,M) -> (y, final SSMCache-contents)."""
        cfg, s = self.cfg, self.cfg.ssm
        di, nh = self.d_inner, self.nheads
        h_in = L.rms_norm(x, p["ln"], cfg.norm_eps)
        zxbcdt = h_in @ p["in_proj"].astype(x.dtype)
        z, xbc_raw, dt_raw = self._split(zxbcdt)
        # causal depthwise conv
        w = p["conv_w"].astype(x.dtype)
        pad = jnp.pad(xbc_raw, ((0, 0), (s.conv_width - 1, 0), (0, 0)))
        conv = sum(pad[:, i:i + xbc_raw.shape[1], :] * w[i]
                   for i in range(s.conv_width))
        xbc = jax.nn.silu(conv + p["conv_b"].astype(x.dtype))
        gn = s.ngroups * s.state_dim
        xin = xbc[..., :di]
        b = xbc[..., di:di + gn].reshape(*xbc.shape[:2], s.ngroups, s.state_dim)
        c = xbc[..., di + gn:].reshape(*xbc.shape[:2], s.ngroups, s.state_dim)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + p["dt_bias"]).astype(jnp.float32)
        a_log = -jnp.exp(p["a_log"])
        xh = xin.reshape(*xin.shape[:2], nh, s.head_dim)
        y, final_state = ssd_chunked(
            xh.astype(jnp.float32), dt, a_log, b.astype(jnp.float32),
            c.astype(jnp.float32), p["d_skip"], s.chunk_size)
        y = y.reshape(*y.shape[:2], di).astype(x.dtype)
        y = L.rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
        out = y @ p["out_proj"].astype(x.dtype)
        # decode conv cache holds the last (w-1) *raw* (pre-conv) xbc inputs
        raw_tail = pad[:, -(s.conv_width - 1):]
        return x + out, (raw_tail, final_state)

    def _block_step(self, p, x_t, cache: SSMCache):
        cfg, s = self.cfg, self.cfg.ssm
        di, nh = self.d_inner, self.nheads
        h_in = L.rms_norm(x_t, p["ln"], cfg.norm_eps)
        zxbcdt = h_in @ p["in_proj"].astype(x_t.dtype)
        z, xbc_t, dt_raw = self._split(zxbcdt)
        window = jnp.concatenate([cache.conv, xbc_t[:, None, :]], axis=1)
        w = p["conv_w"].astype(x_t.dtype)
        conv = jnp.einsum("bwc,wc->bc", window, w)
        xbc = jax.nn.silu(conv + p["conv_b"].astype(x_t.dtype))
        gn = s.ngroups * s.state_dim
        xin = xbc[..., :di]
        b = xbc[..., di:di + gn].reshape(-1, s.ngroups, s.state_dim)
        c = xbc[..., di + gn:].reshape(-1, s.ngroups, s.state_dim)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
        a_log = -jnp.exp(p["a_log"])
        xh = xin.reshape(-1, nh, s.head_dim)
        y, state = ssd_step(cache.state, xh.astype(jnp.float32), dt, a_log,
                            b.astype(jnp.float32), c.astype(jnp.float32),
                            p["d_skip"])
        y = y.reshape(-1, di).astype(x_t.dtype)
        y = L.rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
        out = y @ p["out_proj"].astype(x_t.dtype)
        new_cache = SSMCache(conv=window[:, 1:], state=state,
                             count=cache.count + 1)
        return x_t + out, new_cache

    def forward(self, params, batch, aqua_proj=None, capture: bool = False):
        cfg = self.cfg
        x = L.embed(params["embed"], batch["tokens"], self.dtype)

        from repro.distributed.sharding import constrain_seq

        def body(xc, p_i):
            y, _ = self._block_seq(p_i, xc)
            return constrain_seq(y), None
        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = _scan(body_fn, x, params["layers"])
        logits = L.unembed(params["embed"],
                           L.rms_norm(x, params["ln_f"], cfg.norm_eps))
        return logits

    def init_decode_state(self, batch_size: int, max_seq: int) -> DecodeState:
        cfg, s = self.cfg, self.cfg.ssm
        one = SSMCache(
            conv=jnp.zeros((batch_size, s.conv_width - 1, self.conv_channels),
                           self.dtype),
            state=jnp.zeros((batch_size, self.nheads, s.head_dim,
                             s.state_dim), jnp.float32),
            count=jnp.zeros((batch_size,), jnp.int32))
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), one)
        return DecodeState(layers=stacked, extra={})

    def prefill(self, params, batch, max_seq: int, aqua_proj=None):
        cfg, s = self.cfg, self.cfg.ssm
        x = L.embed(params["embed"], batch["tokens"], self.dtype)
        bsz = x.shape[0]

        def body(xc, p_i):
            y, (conv_tail, state) = self._block_seq(p_i, xc)
            cache = SSMCache(conv=conv_tail.astype(self.dtype),
                             state=state,
                             count=jnp.full((bsz,), xc.shape[1], jnp.int32))
            return y, cache
        x, caches = _scan(body, x, params["layers"])
        logits = L.unembed(params["embed"],
                           L.rms_norm(x[:, -1:], params["ln_f"],
                                      cfg.norm_eps))[:, 0]
        return logits, DecodeState(layers=caches, extra={})

    def decode_step(self, params, state: DecodeState, tokens, aqua_proj=None,
                    write_mask=None):
        cfg = self.cfg
        x = L.embed(params["embed"], tokens, self.dtype)

        def body(xc, layer_in):
            p_i, cache_i = layer_in
            y, cache_i = self._block_step(p_i, xc, cache_i)
            return y, cache_i
        x, caches = _scan(body, x, (params["layers"], state.layers))
        logits = L.unembed(params["embed"],
                           L.rms_norm(x, params["ln_f"], cfg.norm_eps))
        new_state = DecodeState(layers=caches, extra=state.extra)
        if write_mask is not None:
            new_state = self.freeze_rows(new_state, state, write_mask)
        return logits, new_state
