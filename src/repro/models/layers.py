"""Shared model layers: norms, MLPs, embeddings, positional encodings."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.attention import rms_norm  # noqa: F401  (re-export)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def init_linear(rng, d_in: int, d_out: int, dtype=jnp.float32,
                bias: bool = False) -> dict:
    p = {"w": jax.random.normal(rng, (d_in, d_out), dtype) * d_in ** -0.5}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_mlp(rng, d_model: int, d_ff: int, gated: bool = True,
             dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    p = {"w1": jax.random.normal(k1, (d_model, d_ff), dtype) * d_model ** -0.5,
         "w2": jax.random.normal(k2, (d_ff, d_model), dtype) * d_ff ** -0.5}
    if gated:
        p["w3"] = jax.random.normal(k3, (d_model, d_ff), dtype) * d_model ** -0.5
    return p


def mlp(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    h = x @ p["w1"].astype(x.dtype)
    h = act_fn(act)(h)
    if "w3" in p:
        h = h * (x @ p["w3"].astype(x.dtype))
    return h @ p["w2"].astype(x.dtype)


def init_embedding(rng, vocab: int, d_model: int, dtype=jnp.float32) -> dict:
    return {"table": jax.random.normal(rng, (vocab, d_model), dtype)
            * d_model ** -0.5}


def embed(p: dict, tokens: jax.Array, dtype) -> jax.Array:
    return p["table"].astype(dtype)[tokens]


def unembed(p: dict, x: jax.Array) -> jax.Array:
    # logits in f32 for stable softmax/CE
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      p["table"].astype(jnp.float32))


def sinusoidal_positions(seq: int, d_model: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.zeros((seq, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle[:, : (d_model - d_model // 2)]))
    return pe


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE. logits: (B,S,V) f32; labels: (B,S) int32.

    The gold logit is extracted with an iota-compare reduction rather than
    take_along_axis: with vocab-sharded logits (TP) the gather would force
    GSPMD to all-gather the full (B,S,V) f32 logits (tens of GB at
    train_4k); the compare+sum form reduces locally per vocab shard and
    psums a (B,S) scalar instead.
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    onehot = (vocab_iota == labels[..., None]).astype(logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(nll.dtype)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
