"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local sliding
attention, pattern (recurrent, recurrent, attention) (arXiv:2402.19427).

The recurrent path is a real-gated linear recurrence computed with an
associative scan (log-depth, matmul-free); the attention layers use the
shared attention core (so AQUA applies to them — DESIGN.md §4). Bounded
window + O(1) recurrent state make this arch run ``long_500k``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import attention as attn
from repro.core import kvcache as kv
from repro.core.kvcache import RGLRUCache
from repro.models import layers as L
from repro.models.base import LM, DecodeState
from repro.models.transformer import block_forward, block_step, init_block

_C = 8.0  # RG-LRU exponent constant (Griffin §2.4)


def rglru_scan(x: jax.Array, r: jax.Array, i_gate: jax.Array,
               lam: jax.Array, h0: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, jax.Array]:
    """x, r, i_gate: (B, S, W); lam: (W,). h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t x_t).

    Returns (hidden sequence (B,S,W), final hidden (B,W))."""
    log_a = -_C * jax.nn.softplus(lam)[None, None, :] * r  # (B,S,W) <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_gate * x)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2
    a_s, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        h = h + a_s * h0[:, None, :]
    return h, h[:, -1, :]


def rglru_step(x_t, r_t, i_t, lam, h_prev):
    log_a = -_C * jax.nn.softplus(lam)[None, :] * r_t
    a = jnp.exp(log_a)
    h = a * h_prev + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_t * x_t)
    return h, h


def init_recurrent_block(rng, cfg: ModelConfig, dtype) -> dict:
    w = cfg.rglru.lru_width or cfg.d_model
    ks = jax.random.split(rng, 7)
    std = cfg.d_model ** -0.5
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "wx": jax.random.normal(ks[0], (cfg.d_model, w), dtype) * std,
        "wgate": jax.random.normal(ks[1], (cfg.d_model, w), dtype) * std,
        "conv_w": jax.random.normal(ks[2], (cfg.rglru.conv_width, w), dtype)
        * cfg.rglru.conv_width ** -0.5,
        "conv_b": jnp.zeros((w,), dtype),
        "wr": jax.random.normal(ks[3], (w, w), jnp.float32) * w ** -0.5,
        "wi": jax.random.normal(ks[4], (w, w), jnp.float32) * w ** -0.5,
        "lam": jnp.full((w,), 1.0, jnp.float32),
        "wout": jax.random.normal(ks[5], (w, cfg.d_model), dtype) * w ** -0.5,
        "ffn": L.init_mlp(ks[6], cfg.d_model, cfg.d_ff,
                          gated=(cfg.act == "silu"), dtype=dtype),
    }


def _conv1d_causal(x, w, b):
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    return sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(width)) + b


def recurrent_block_forward(cfg: ModelConfig, p: dict, x: jax.Array,
                            h0: Optional[jax.Array] = None):
    """Returns (y, (conv_tail, final_hidden))."""
    h_in = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    gate = jax.nn.gelu(h_in @ p["wgate"].astype(x.dtype))
    u_raw = h_in @ p["wx"].astype(x.dtype)
    u = _conv1d_causal(u_raw, p["conv_w"].astype(x.dtype),
                       p["conv_b"].astype(x.dtype))
    # gate matmuls in activation dtype: on a TP-sharded W×W gate the
    # (B,S,W) product is all-reduced across the model axis — bf16 halves
    # that collective + HBM traffic (§Perf iteration, recurrentgemma);
    # sigmoid/scan run in f32 for stability.
    from repro.distributed.sharding import constrain_lru_gate
    r = jax.nn.sigmoid(constrain_lru_gate(
        u @ p["wr"].astype(u.dtype)).astype(jnp.float32))
    i_g = jax.nn.sigmoid(constrain_lru_gate(
        u @ p["wi"].astype(u.dtype)).astype(jnp.float32))
    u32 = u.astype(jnp.float32)
    h, h_last = rglru_scan(u32, r, i_g, p["lam"], h0)
    y = (h.astype(x.dtype) * gate) @ p["wout"].astype(x.dtype)
    x = x + y
    f = L.mlp(p["ffn"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg.act)
    width = cfg.rglru.conv_width
    conv_tail = jnp.pad(u_raw, ((0, 0), (width - 1, 0), (0, 0))
                        )[:, -(width - 1):]
    return x + f, (conv_tail, h_last)


def recurrent_block_step(cfg: ModelConfig, p: dict, x_t: jax.Array,
                         cache: RGLRUCache):
    h_in = L.rms_norm(x_t, p["ln1"], cfg.norm_eps)
    gate = jax.nn.gelu(h_in @ p["wgate"].astype(x_t.dtype))
    u_raw = h_in @ p["wx"].astype(x_t.dtype)
    window = jnp.concatenate([cache.conv, u_raw[:, None, :]], axis=1)
    u = jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(x_t.dtype)) \
        + p["conv_b"].astype(x_t.dtype)
    r = jax.nn.sigmoid((u @ p["wr"].astype(u.dtype)).astype(jnp.float32))
    i_g = jax.nn.sigmoid((u @ p["wi"].astype(u.dtype)).astype(jnp.float32))
    u32 = u.astype(jnp.float32)
    h, _ = rglru_step(u32, r, i_g, p["lam"], cache.state)
    y = (h.astype(x_t.dtype) * gate) @ p["wout"].astype(x_t.dtype)
    x = x_t + y
    f = L.mlp(p["ffn"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg.act)
    new_cache = RGLRUCache(conv=window[:, 1:], state=h,
                           count=cache.count + 1)
    return x + f, new_cache


class HybridLM(LM):
    """recurrentgemma-9b family. Layers follow cfg.rglru.block_pattern
    cyclically; unrolled python loop (heterogeneous layer types)."""

    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        pat = cfg.rglru.block_pattern
        self.kinds = tuple(pat[i % len(pat)] for i in range(cfg.num_layers))

    def init(self, rng: jax.Array):
        cfg, dt = self.cfg, self.param_dtype
        k_emb, k_layers = jax.random.split(rng)
        rngs = jax.random.split(k_layers, cfg.num_layers)
        layers = []
        for i, kind in enumerate(self.kinds):
            if kind == "recurrent":
                layers.append(init_recurrent_block(rngs[i], cfg, dt))
            else:
                layers.append(init_block(rngs[i], cfg, dt))
        return {
            "embed": L.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dt),
            "layers": layers,
            "ln_f": jnp.ones((cfg.d_model,), dt),
        }

    def forward(self, params, batch, aqua_proj=None, capture: bool = False):
        cfg = self.cfg
        x = L.embed(params["embed"], batch["tokens"], self.dtype)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        from repro.distributed.sharding import constrain_seq
        qk = []
        attn_idx = 0
        for i, kind in enumerate(self.kinds):
            p_i = params["layers"][i]
            if kind == "recurrent":
                fwd = (jax.checkpoint(recurrent_block_forward,
                                      static_argnums=(0,))
                       if cfg.remat and not capture else recurrent_block_forward)
                x, _ = fwd(cfg, p_i, x)
            else:
                proj = None if aqua_proj is None else aqua_proj[attn_idx]
                if capture:
                    x, _, aux = block_forward(cfg, p_i, x, positions, proj,
                                              capture=True)
                    qk.append((aux["q"], aux["k"]))
                else:
                    fwd = (jax.checkpoint(block_forward, static_argnums=(0,))
                           if cfg.remat else block_forward)
                    x, _, _ = fwd(cfg, p_i, x, positions, proj)
                attn_idx += 1
            x = constrain_seq(x)
        logits = L.unembed(params["embed"],
                           L.rms_norm(x, params["ln_f"], cfg.norm_eps))
        if capture:
            return logits, {"qk": qk}
        return logits

    @property
    def num_attn_layers(self) -> int:
        return sum(1 for k in self.kinds if k == "attention")

    def init_decode_state(self, batch_size: int, max_seq: int) -> DecodeState:
        cfg, acfg = self.cfg, self.cfg.attention
        aqua = cfg.aqua
        dk = acfg.head_dim
        if aqua is not None and aqua.enabled:
            dk = aqua.kept_dims(acfg.head_dim)
        from repro.core.h2o import h2o_budget
        slots = kv.cache_slots(max_seq, acfg.window, h2o_budget(aqua, max_seq))
        w = cfg.rglru.lru_width or cfg.d_model
        layers = []
        for kind in self.kinds:
            if kind == "recurrent":
                layers.append(RGLRUCache(
                    conv=jnp.zeros((batch_size, cfg.rglru.conv_width - 1, w),
                                   self.dtype),
                    state=jnp.zeros((batch_size, w), jnp.float32),
                    count=jnp.zeros((batch_size,), jnp.int32)))
            else:
                layers.append(kv.init_attn_cache(
                    batch_size, acfg.num_kv_heads, slots, dk, acfg.head_dim,
                    self.dtype))
        return DecodeState(layers=tuple(layers), extra={})

    def prefill(self, params, batch, max_seq: int, aqua_proj=None):
        cfg = self.cfg
        x = L.embed(params["embed"], batch["tokens"], self.dtype)
        bsz, s = x.shape[0], x.shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        caches = []
        attn_idx = 0
        for i, kind in enumerate(self.kinds):
            p_i = params["layers"][i]
            if kind == "recurrent":
                y, (conv_tail, h_last) = recurrent_block_forward(cfg, p_i, x)
                caches.append(RGLRUCache(
                    conv=conv_tail.astype(self.dtype), state=h_last,
                    count=jnp.full((bsz,), s, jnp.int32)))
                x = y
            else:
                proj = None if aqua_proj is None else aqua_proj[attn_idx]
                caches.append(attn.build_cache_from_prefill(
                    p_i["attn"], L.rms_norm(x, p_i["ln1"], cfg.norm_eps),
                    cfg.attention, cfg.aqua, proj, max_seq))
                x, _, _ = block_forward(cfg, p_i, x, positions, proj)
                attn_idx += 1
        logits = L.unembed(params["embed"],
                           L.rms_norm(x[:, -1:], params["ln_f"],
                                      cfg.norm_eps))[:, 0]
        return logits, DecodeState(layers=tuple(caches), extra={})

    def decode_step(self, params, state: DecodeState, tokens, aqua_proj=None,
                    write_mask=None):
        cfg = self.cfg
        x = L.embed(params["embed"], tokens, self.dtype)
        caches = []
        attn_idx = 0
        for i, kind in enumerate(self.kinds):
            p_i = params["layers"][i]
            cache_i = state.layers[i]
            if kind == "recurrent":
                x, cache_i = recurrent_block_step(cfg, p_i, x, cache_i)
                if write_mask is not None:
                    cache_i = jax.tree.map(
                        lambda new, old: jnp.where(
                            write_mask.reshape((-1,) + (1,) * (new.ndim - 1)),
                            new, old),
                        cache_i, state.layers[i])
            else:
                proj = None if aqua_proj is None else aqua_proj[attn_idx]
                x, cache_i = block_step(cfg, p_i, x, cache_i, proj,
                                        write_mask=write_mask)
                attn_idx += 1
            caches.append(cache_i)
        logits = L.unembed(params["embed"],
                           L.rms_norm(x, params["ln_f"], cfg.norm_eps))
        return logits, DecodeState(layers=tuple(caches), extra=state.extra)

    # HybridLM stores per-layer caches unstacked (tuple of (B, ...) pytrees,
    # batch at axis 0), so the base class's axis-1 lane surgery does not
    # apply — override with axis-0 indexing.
    def insert_lane(self, state: DecodeState, req_state: DecodeState,
                    lane):
        lane_set = lambda dst, src: dst.at[lane].set(src[0])
        return self.constrain_state(DecodeState(
            layers=jax.tree.map(lane_set, state.layers, req_state.layers),
            extra=jax.tree.map(lane_set, state.extra, req_state.extra)))
