"""Dense / VLM transformer LMs and the whisper-style encoder-decoder.

Layers are *stacked* (leading L axis) and iterated with ``jax.lax.scan`` so
HLO size and compile time stay flat in depth — essential for the 512-device
dry-run. The calibration/capture path uses an unrolled loop instead (small
models only).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from repro import runtime_flags as _rtf


def _scan(*args, **kw):
    kw.update(_rtf.scan_kwargs())
    return jax.lax.scan(*args, **kw)


from repro.configs.base import ModelConfig
from repro.core import attention as attn
from repro.core import kvcache as kv
from repro.models import layers as L
from repro.models.base import LM, DecodeState


# ---------------------------------------------------------------------------
# One transformer block (attention + FFN), stacked-params form.
# ---------------------------------------------------------------------------


def init_block(rng, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(rng)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_attention_params(k1, cfg.d_model, cfg.attention,
                                           dtype),
    }
    if cfg.family == "moe":
        from repro.models.moe import init_moe_ffn
        p["ffn"] = init_moe_ffn(k2, cfg, dtype)
    else:
        p["ffn"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff,
                              gated=(cfg.act == "silu"), dtype=dtype)
    return p


def ffn_apply(cfg: ModelConfig, p: dict, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    if cfg.family == "moe":
        from repro.models.moe import moe_ffn
        return moe_ffn(cfg, p, x)
    return L.mlp(p, x, cfg.act), jnp.zeros((), jnp.float32)


def block_forward(cfg: ModelConfig, p: dict, x: jax.Array,
                  positions: jax.Array, proj: Optional[jax.Array],
                  capture: bool = False,
                  lengths: Optional[jax.Array] = None):
    """One block. Attention dispatches through the backend registry in
    ``repro.core.attention`` (``cfg.attention.backend``); ``lengths``
    threads ragged per-row valid lengths into the prefill kernels."""
    aqua = cfg.aqua
    h_in = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if capture:
        h, aux = attn.prefill_attention(p["attn"], h_in, cfg.attention, aqua,
                                        proj, positions, return_aux=True,
                                        lengths=lengths)
    else:
        h = attn.prefill_attention(p["attn"], h_in, cfg.attention, aqua,
                                   proj, positions, lengths=lengths)
        aux = None
    x = x + h
    f, aux_loss = ffn_apply(cfg, p["ffn"], L.rms_norm(x, p["ln2"], cfg.norm_eps))
    return x + f, aux_loss, aux


def block_step(cfg: ModelConfig, p: dict, x_t: jax.Array, cache: kv.AttnCache,
               proj: Optional[jax.Array],
               write_mask: Optional[jax.Array] = None):
    h, cache = attn.decode_attention(
        p["attn"], L.rms_norm(x_t, p["ln1"], cfg.norm_eps), cache,
        cfg.attention, cfg.aqua, proj, write_mask=write_mask)
    x = x_t + h
    f, _ = ffn_apply(cfg, p["ffn"],
                     L.rms_norm(x, p["ln2"], cfg.norm_eps)[:, None, :])
    return x + f[:, 0], cache


# ---------------------------------------------------------------------------
# DenseLM — dense & vlm families
# ---------------------------------------------------------------------------


class DenseLM(LM):
    """Decoder-only transformer (GQA/SWA/qk-norm/bias variants) with
    first-class AQUA. ``vlm`` family splices stub patch embeddings.

    Supports the block-paged decode state (``enable_paging``): the per
    layer cache becomes a global page pool + per-lane page tables, lane
    admission grafts through ``graft_paged`` / ``prefill_with_prefix``
    instead of the contiguous ``insert_lane`` row scatter."""

    supports_paging = True

    def init(self, rng: jax.Array):
        cfg, dt = self.cfg, self.param_dtype
        k_emb, k_layers, k_fe = jax.random.split(rng, 3)
        layer_rngs = jax.random.split(k_layers, cfg.num_layers)
        params = {
            "embed": L.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dt),
            "layers": jax.vmap(lambda r: init_block(r, cfg, dt))(layer_rngs),
            "ln_f": jnp.ones((cfg.d_model,), dt),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = {"table": jax.random.normal(
                jax.random.fold_in(k_emb, 1), (cfg.vocab_size, cfg.d_model),
                dt) * cfg.d_model ** -0.5}
        if cfg.frontend.kind == "vision_patches":
            params["patch_proj"] = L.init_linear(
                k_fe, cfg.frontend.embed_dim, cfg.d_model, dt)
        return params

    # -- embedding helpers -------------------------------------------
    def _embed(self, params, batch):
        x = L.embed(params["embed"], batch["tokens"], self.dtype)
        if self.cfg.frontend.kind == "vision_patches" and "patches" in batch:
            pe = L.linear(params["patch_proj"],
                          batch["patches"].astype(self.dtype))
            n = pe.shape[1]
            x = x.at[:, :n, :].set(pe)
        return x

    def _unembed(self, params, x):
        table = params["embed" if self.cfg.tie_embeddings else "unembed"]
        return L.unembed(table, x)

    # -- full-sequence forward ----------------------------------------
    def forward(self, params, batch, aqua_proj: Optional[jax.Array] = None,
                capture: bool = False):
        cfg = self.cfg
        x = self._embed(params, batch)
        s = x.shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        if capture:
            qk, aux_losses = [], 0.0
            for i in range(cfg.num_layers):
                p_i = jax.tree.map(lambda a: a[i], params["layers"])
                proj = None if aqua_proj is None else aqua_proj[i]
                x, al, aux = block_forward(cfg, p_i, x, positions, proj,
                                           capture=True)
                qk.append((aux["q"], aux["k"]))
                aux_losses += al
            logits = self._unembed(params, L.rms_norm(x, params["ln_f"],
                                                      cfg.norm_eps))
            return logits, {"qk": qk, "aux_loss": aux_losses}

        from repro.distributed.sharding import constrain_seq

        def body(carry, layer_in):
            xc = carry
            p_i, proj_i = layer_in
            y, al, _ = block_forward(cfg, p_i, xc, positions, proj_i)
            return constrain_seq(y), al
        body_fn = jax.checkpoint(body) if cfg.remat else body
        proj_stack = (aqua_proj if aqua_proj is not None
                      else jnp.zeros((cfg.num_layers, 0), self.dtype))
        proj_arg = aqua_proj  # None or (L, KV, D, D)
        if proj_arg is None:
            x, aux_losses = _scan(
                lambda c, p_i: body_fn(c, (p_i, None)), x, params["layers"])
        else:
            x, aux_losses = _scan(body_fn, x,
                                         (params["layers"], proj_arg))
        logits = self._unembed(params, L.rms_norm(x, params["ln_f"],
                                                  cfg.norm_eps))
        if cfg.family == "moe":
            return logits, {"aux_loss": aux_losses.sum()
                            * cfg.moe.router_aux_weight}
        return logits

    # -- serving --------------------------------------------------------
    def _cache_shape(self, max_seq: int):
        cfg, acfg, aqua = self.cfg, self.cfg.attention, self.cfg.aqua
        dk = acfg.head_dim
        if aqua is not None and aqua.enabled:
            dk = aqua.kept_dims(acfg.head_dim)
        from repro.core.h2o import h2o_budget
        slots = kv.cache_slots(max_seq, acfg.window, h2o_budget(aqua, max_seq))
        return slots, dk, acfg.head_dim

    def init_decode_state(self, batch_size: int, max_seq: int) -> DecodeState:
        cfg, acfg = self.cfg, self.cfg.attention
        slots, dk, dv = self._cache_shape(max_seq)
        pg = self._paging
        if pg is not None:
            npl = kv.paged_pages(slots, pg.page_size)
            one = lambda: kv.init_paged_cache(
                batch_size, acfg.num_kv_heads, pg.num_pages, npl,
                pg.page_size, dk, dv, self.dtype, kv_dtype=pg.kv_dtype,
                scale_granularity=pg.scale_granularity,
                hot_pages=pg.hot_pages)
        else:
            one = lambda: kv.init_attn_cache(batch_size, acfg.num_kv_heads,
                                             slots, dk, dv, self.dtype)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), one())
        return DecodeState(layers=stacked, extra={})

    # -- paged lane surgery -------------------------------------------
    def graft_paged(self, state: DecodeState, req_state: DecodeState,
                    lane: jax.Array, num_slots: int) -> DecodeState:
        """Copy logical slots [0, num_slots) of a B=1 *contiguous* prefill
        cache into ``lane``'s pages, layer by layer. The page-table row
        must already be installed (host allocator via the engine)."""
        layers = jax.vmap(
            lambda c, r: kv.paged_graft(c, r, lane, num_slots)
        )(state.layers, req_state.layers)
        return self.constrain_state(DecodeState(layers=layers,
                                                extra=state.extra))

    def reset_lane(self, state: DecodeState, lane: jax.Array,
                   max_seq: int) -> DecodeState:
        if self._paging is None:
            return super().reset_lane(state, lane, max_seq)
        layers = jax.vmap(kv.paged_reset_lane, in_axes=(0, None)
                          )(state.layers, lane)
        return self.constrain_state(DecodeState(layers=layers,
                                                extra=state.extra))

    def prefill_with_prefix(self, params, batch, state: DecodeState,
                            lane: jax.Array, prefix_len: jax.Array,
                            aqua_proj=None, select_q_blk=None):
        """Prefix-shared admission: prefill only the prompt *tail* —
        queries attend to the shared prefix K/V read from the lane's
        mapped pool pages (written by an earlier request's prefill), and
        only tail K/V is written, into the lane's private pages. The
        prefix is never recomputed and never written (copy-on-write
        territory starts at the page-aligned divergence point).

        The same cache-extension step also serves *chunked* prefill
        (``prefill_chunk`` alias): there ``prefix_len`` is the chunk
        cursor and the "prefix" is simply the part of the same prompt an
        earlier chunk already wrote. ``select_q_blk`` (static) switches
        the AQUA dim-block selection to the block-sparse kernel's
        per-tile aggregation — the chunked engine passes it for fresh
        (non-prefix-shared) prompts so every chunk selects exactly the
        blocks the monolithic kernel admission would (chunk cursors stay
        q_blk-aligned).
        """
        cfg = self.cfg
        tokens = batch["tokens"]                       # (1, T_pad) tail
        lengths = batch.get("lengths")                 # (1,) valid tail
        t = tokens.shape[1]
        x = L.embed(params["embed"], tokens, self.dtype)
        positions = prefix_len + jnp.arange(t, dtype=jnp.int32)[None]
        paged = self._paging is not None
        if paged:
            ps = state.layers.k_pool.shape[3]  # stacked (L, P, KV, ps, Dk)
            start_page = prefix_len // ps
        tail_count = (prefix_len + t if lengths is None
                      else prefix_len + lengths[0])

        def body(xc, layer_in):
            p_i, cache_i, proj_i = layer_in
            s_log = cache_i.num_slots
            if paged:
                # gathered + (for int8 pools) dequantized lane view of the
                # already-written prefix — quantization never leaks past
                # the pool boundary into the attention math
                pk, pv, ppos = kv.paged_lane_pages(cache_i, lane,
                                                   dtype=self.dtype)
            else:
                pk = cache_i.k[lane][None]                # (1, KV, S, Dk)
                pv = cache_i.v[lane][None]
                ppos = cache_i.positions[lane][None]      # (1, S)
            # trust only logical slots [0, prefix_len): the lane's private
            # tail/decode slots are *recycled* (pool pages or a contiguous
            # stripe) and still hold a previous tenant's positions until
            # the write-tail below clears them AFTER this read — a stale
            # position inside the prefix range would otherwise pass the
            # prefix validity mask and attend over dead K/V. Full-cache
            # policy: prefix token p lives at logical slot p, so the
            # slot-index mask is exact.
            ppos = jnp.where(jnp.arange(s_log)[None] < prefix_len, ppos, -1)
            h_in = L.rms_norm(xc, p_i["ln1"], cfg.norm_eps)
            h, k_t, v_t = attn.prefixed_tail_attention(
                p_i["attn"], h_in, cfg.attention, cfg.aqua, proj_i,
                prefix_k=pk, prefix_v=pv, prefix_positions=ppos,
                prefix_len=prefix_len, positions=positions,
                lengths=lengths, select_q_blk=select_q_blk)
            y = xc + h
            f, _ = ffn_apply(cfg, p_i["ffn"],
                             L.rms_norm(y, p_i["ln2"], cfg.norm_eps))
            if paged:
                cache_i = kv.paged_write_tail(cache_i, lane, k_t[0], v_t[0],
                                              positions[0], start_page,
                                              tail_count)
            else:
                cache_i = kv.lane_write_tail(cache_i, lane, k_t[0], v_t[0],
                                             positions[0], prefix_len,
                                             tail_count)
            return y + f, cache_i
        if aqua_proj is None:
            x, caches = _scan(lambda c, pi: body(c, (pi[0], pi[1], None)),
                              x, (params["layers"], state.layers))
        else:
            x, caches = _scan(body, x, (params["layers"], state.layers,
                                        aqua_proj))
        if lengths is None:
            x_last = x[:, -1:]
        else:
            idx = jnp.clip(lengths - 1, 0, x.shape[1] - 1)
            x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        logits = self._unembed(params, L.rms_norm(x_last, params["ln_f"],
                                                  cfg.norm_eps))[:, 0]
        return logits, self.constrain_state(
            DecodeState(layers=caches, extra=state.extra))

    # Chunked prefill advances a lane's cache by one page-aligned chunk:
    # exactly a prefix-shared tail where the "prefix" is what earlier
    # chunks of the same prompt already wrote (contiguous stripes reuse
    # the same step via kv.lane_write_tail).
    prefill_chunk = prefill_with_prefix

    def prefill(self, params, batch, max_seq: int,
                aqua_proj: Optional[jax.Array] = None):
        cfg = self.cfg
        x = self._embed(params, batch)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        # optional ragged prompts: batch["lengths"] (B,) valid prefix sizes
        lengths = batch.get("lengths")

        def body(xc, layer_in):
            p_i, proj_i = layer_in
            y, _, _ = block_forward(cfg, p_i, xc, positions, proj_i,
                                    lengths=lengths)
            cache = attn.build_cache_from_prefill(
                p_i["attn"], L.rms_norm(xc, p_i["ln1"], cfg.norm_eps),
                cfg.attention, cfg.aqua, proj_i, max_seq, lengths=lengths)
            return y, cache
        if aqua_proj is None:
            x, caches = _scan(lambda c, p_i: body(c, (p_i, None)),
                                     x, params["layers"])
        else:
            x, caches = _scan(body, x, (params["layers"], aqua_proj))
        if lengths is None:
            x_last = x[:, -1:]
        else:
            # ragged rows: next-token logits come from each row's last
            # *valid* token, not the padding tail
            idx = jnp.clip(lengths - 1, 0, x.shape[1] - 1)
            x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        logits = self._unembed(params, L.rms_norm(x_last, params["ln_f"],
                                                  cfg.norm_eps))[:, 0]
        return logits, DecodeState(layers=caches, extra={})

    def decode_step(self, params, state: DecodeState, tokens: jax.Array,
                    aqua_proj: Optional[jax.Array] = None,
                    write_mask: Optional[jax.Array] = None):
        cfg = self.cfg
        x = L.embed(params["embed"], tokens, self.dtype)  # (B, d)

        def body(xc, layer_in):
            p_i, cache_i, proj_i = layer_in
            y, cache_i = block_step(cfg, p_i, xc, cache_i, proj_i,
                                    write_mask=write_mask)
            return y, cache_i
        if aqua_proj is None:
            x, caches = _scan(
                lambda c, pi: body(c, (pi[0], pi[1], None)),
                x, (params["layers"], state.layers))
        else:
            x, caches = _scan(body, x, (params["layers"], state.layers,
                                               aqua_proj))
        logits = self._unembed(params, L.rms_norm(x, params["ln_f"],
                                                  cfg.norm_eps))
        return logits, DecodeState(layers=caches, extra=state.extra)


# ---------------------------------------------------------------------------
# Whisper-style encoder-decoder
# ---------------------------------------------------------------------------


def init_decoder_block(rng, cfg: ModelConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln_x": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_attention_params(k1, cfg.d_model, cfg.attention,
                                           dtype),
        "xattn": attn.init_attention_params(k2, cfg.d_model, cfg.attention,
                                            dtype),
        "ffn": L.init_mlp(k3, cfg.d_model, cfg.d_ff, gated=False, dtype=dtype),
    }


class EncDecLM(LM):
    """Whisper-tiny family: bidirectional encoder over stub frame embeddings,
    causal decoder with cross-attention. AQUA applies to decoder self-attn."""

    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        self.enc_attn = dataclasses.replace(cfg.attention, causal=False,
                                            use_rope=False)

    def init(self, rng: jax.Array):
        cfg, dt = self.cfg, self.param_dtype
        ks = jax.random.split(rng, 4)
        enc_rngs = jax.random.split(ks[0], cfg.num_encoder_layers)
        dec_rngs = jax.random.split(ks[1], cfg.num_layers)
        enc_cfg = dataclasses.replace(cfg, attention=self.enc_attn,
                                      family="dense", act="gelu")
        return {
            "embed": L.init_embedding(ks[2], cfg.vocab_size, cfg.d_model, dt),
            "pos": jax.random.normal(ks[3], (cfg.max_positions, cfg.d_model),
                                     dt) * 0.01,
            "enc_layers": jax.vmap(lambda r: init_block(r, enc_cfg, dt))(
                enc_rngs),
            "enc_ln": jnp.ones((cfg.d_model,), dt),
            "dec_layers": jax.vmap(lambda r: init_decoder_block(r, cfg, dt))(
                dec_rngs),
            "ln_f": jnp.ones((cfg.d_model,), dt),
        }

    def encode(self, params, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = frames.astype(self.dtype)
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model
                                       ).astype(self.dtype)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        enc_cfg = dataclasses.replace(cfg, attention=self.enc_attn,
                                      family="dense", act="gelu", aqua=None)

        def body(xc, p_i):
            y, _, _ = block_forward(enc_cfg, p_i, xc, positions, None)
            return y, None
        x, _ = _scan(body, x, params["enc_layers"])
        return L.rms_norm(x, params["enc_ln"], cfg.norm_eps)

    def _dec_block_fwd(self, p, x, enc_out, positions, proj, capture=False):
        cfg = self.cfg
        aqua = cfg.aqua
        h = attn.prefill_attention(p["attn"],
                                   L.rms_norm(x, p["ln1"], cfg.norm_eps),
                                   cfg.attention, aqua, proj, positions,
                                   return_aux=capture)
        aux = None
        if capture:
            h, aux = h
        x = x + h
        cx = attn.prefill_attention(p["xattn"],
                                    L.rms_norm(x, p["ln_x"], cfg.norm_eps),
                                    cfg.attention, None, None, positions,
                                    kv_x=enc_out)
        x = x + cx
        f = L.mlp(p["ffn"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg.act)
        return x + f, aux

    def forward(self, params, batch, aqua_proj: Optional[jax.Array] = None,
                capture: bool = False):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        s = tokens.shape[1]
        x = L.embed(params["embed"], tokens, self.dtype)
        x = x + params["pos"][:s].astype(self.dtype)
        positions = jnp.arange(s, dtype=jnp.int32)
        if capture:
            qk = []
            for i in range(cfg.num_layers):
                p_i = jax.tree.map(lambda a: a[i], params["dec_layers"])
                proj = None if aqua_proj is None else aqua_proj[i]
                x, aux = self._dec_block_fwd(p_i, x, enc_out, positions, proj,
                                             capture=True)
                qk.append((aux["q"], aux["k"]))
            logits = L.unembed(params["embed"],
                               L.rms_norm(x, params["ln_f"], cfg.norm_eps))
            return logits, {"qk": qk}

        from repro.distributed.sharding import constrain_seq

        def body(xc, layer_in):
            p_i, proj_i = layer_in
            y, _ = self._dec_block_fwd(p_i, xc, enc_out, positions, proj_i)
            return constrain_seq(y), None
        body_fn = jax.checkpoint(body) if cfg.remat else body
        if aqua_proj is None:
            x, _ = _scan(lambda c, p_i: body_fn(c, (p_i, None)),
                                x, params["dec_layers"])
        else:
            x, _ = _scan(body_fn, x, (params["dec_layers"], aqua_proj))
        return L.unembed(params["embed"],
                         L.rms_norm(x, params["ln_f"], cfg.norm_eps))

    # -- serving -------------------------------------------------------
    def init_decode_state(self, batch_size: int, max_seq: int) -> DecodeState:
        cfg, acfg = self.cfg, self.cfg.attention
        aqua = cfg.aqua
        dk = acfg.head_dim
        if aqua is not None and aqua.enabled:
            dk = aqua.kept_dims(acfg.head_dim)
        from repro.core.h2o import h2o_budget
        slots = kv.cache_slots(max_seq, acfg.window, h2o_budget(aqua, max_seq))
        one = kv.init_attn_cache(batch_size, acfg.num_kv_heads, slots, dk,
                                 acfg.head_dim, self.dtype)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), one)
        n_frames = cfg.frontend.num_embeds
        cross = (jnp.zeros((cfg.num_layers, batch_size, n_frames,
                            acfg.num_kv_heads, acfg.head_dim), self.dtype),
                 jnp.zeros((cfg.num_layers, batch_size, n_frames,
                            acfg.num_kv_heads, acfg.head_dim), self.dtype))
        return DecodeState(layers=stacked, extra={"cross": cross})

    def precompute_cross(self, params, enc_out: jax.Array):
        """Per-decoder-layer K/V over encoder output (computed once)."""
        def one(p_x):
            k = jnp.einsum("bsm,mkd->bskd", enc_out,
                           p_x["wk"].astype(enc_out.dtype))
            v = jnp.einsum("bsm,mkd->bskd", enc_out,
                           p_x["wv"].astype(enc_out.dtype))
            if self.cfg.attention.qkv_bias:
                k = k + p_x["bk"].astype(k.dtype)
                v = v + p_x["bv"].astype(v.dtype)
            return k, v
        return jax.vmap(one)(params["dec_layers"]["xattn"])

    def prefill(self, params, batch, max_seq: int,
                aqua_proj: Optional[jax.Array] = None):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        cross = self.precompute_cross(params, enc_out)
        tokens = batch["tokens"]
        s = tokens.shape[1]
        x = L.embed(params["embed"], tokens, self.dtype)
        x = x + params["pos"][:s].astype(self.dtype)
        positions = jnp.arange(s, dtype=jnp.int32)

        def body(xc, layer_in):
            p_i, proj_i = layer_in
            y, _ = self._dec_block_fwd(p_i, xc, enc_out, positions, proj_i)
            cache = attn.build_cache_from_prefill(
                p_i["attn"], L.rms_norm(xc, p_i["ln1"], cfg.norm_eps),
                cfg.attention, cfg.aqua, proj_i, max_seq)
            return y, cache
        if aqua_proj is None:
            x, caches = _scan(lambda c, p_i: body(c, (p_i, None)),
                                     x, params["dec_layers"])
        else:
            x, caches = _scan(body, x,
                                     (params["dec_layers"], aqua_proj))
        logits = L.unembed(params["embed"],
                           L.rms_norm(x[:, -1:], params["ln_f"],
                                      cfg.norm_eps))[:, 0]
        return logits, DecodeState(layers=caches, extra={"cross": cross})

    def decode_step(self, params, state: DecodeState, tokens: jax.Array,
                    aqua_proj: Optional[jax.Array] = None,
                    write_mask: Optional[jax.Array] = None):
        cfg = self.cfg
        pos = state.layers.count[0]  # (B,) shared across layers
        x = L.embed(params["embed"], tokens, self.dtype)
        x = x + params["pos"].astype(self.dtype)[
            jnp.clip(pos, 0, cfg.max_positions - 1)]
        cross_k, cross_v = state.extra["cross"]

        def body(xc, layer_in):
            p_i, cache_i, ck, cv, proj_i = layer_in
            h, cache_i = attn.decode_attention(
                p_i["attn"], L.rms_norm(xc, p_i["ln1"], cfg.norm_eps),
                cache_i, cfg.attention, cfg.aqua, proj_i,
                write_mask=write_mask)
            y = xc + h
            cx, _ = attn.decode_attention(
                p_i["xattn"], L.rms_norm(y, p_i["ln_x"], cfg.norm_eps),
                cache_i, cfg.attention, None, None, cross=(ck, cv))
            y = y + cx
            f = L.mlp(p_i["ffn"], L.rms_norm(y, p_i["ln2"], cfg.norm_eps),
                      cfg.act)
            return y + f, cache_i
        if aqua_proj is None:
            x, caches = _scan(
                lambda c, pi: body(c, (pi[0], pi[1], pi[2], pi[3], None)),
                x, (params["dec_layers"], state.layers, cross_k, cross_v))
        else:
            x, caches = _scan(
                body, x, (params["dec_layers"], state.layers, cross_k,
                          cross_v, aqua_proj))
        logits = L.unembed(params["embed"],
                           L.rms_norm(x, params["ln_f"], cfg.norm_eps))
        return logits, DecodeState(layers=caches, extra=state.extra)
