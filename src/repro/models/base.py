"""Model protocol and decode-state container."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@jax.tree_util.register_dataclass
@dataclass
class DecodeState:
    """Serving state: one cache pytree per layer plus model-level extras
    (e.g. whisper's precomputed cross-attention K/V)."""

    layers: Tuple[Any, ...]
    extra: Dict[str, Any] = field(default_factory=dict)


class LM:
    """Base class: subclasses implement the per-family wiring.

    All methods are pure functions of (params, inputs) and jit-compatible;
    ``self`` only carries the static config.
    """

    def __init__(self, cfg: ModelConfig):
        cfg.validate()
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.param_dtype = jnp.dtype(cfg.param_dtype)

    # -- required API -------------------------------------------------
    def init(self, rng: jax.Array):
        raise NotImplementedError

    def forward(self, params, batch: Dict[str, jax.Array],
                aqua_proj: Optional[jax.Array] = None, capture: bool = False):
        """Full-sequence logits (B, S, V) [, aux]."""
        raise NotImplementedError

    def init_decode_state(self, batch_size: int, max_seq: int) -> DecodeState:
        raise NotImplementedError

    def prefill(self, params, batch, max_seq: int,
                aqua_proj: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, DecodeState]:
        raise NotImplementedError

    def decode_step(self, params, state: DecodeState, tokens: jax.Array,
                    aqua_proj: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, DecodeState]:
        """tokens: (B,) int32 -> (logits (B, V), new state)."""
        raise NotImplementedError

    # -- provided -----------------------------------------------------
    def loss(self, params, batch: Dict[str, jax.Array]):
        from repro.models.layers import cross_entropy
        logits = self.forward(params, batch)
        if isinstance(logits, tuple):
            logits, aux = logits
        else:
            aux = {}
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        l = cross_entropy(logits, labels, mask)
        if "aux_loss" in aux:
            l = l + aux["aux_loss"]
        return l, {"ce": l}
