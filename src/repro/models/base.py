"""Model protocol and decode-state container."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@jax.tree_util.register_dataclass
@dataclass
class DecodeState:
    """Serving state: one cache pytree per layer plus model-level extras
    (e.g. whisper's precomputed cross-attention K/V)."""

    layers: Tuple[Any, ...]
    extra: Dict[str, Any] = field(default_factory=dict)


@dataclass(frozen=True)
class PagingSpec:
    """Block-paged KV cache geometry installed on a model by the serving
    engine (``LM.enable_paging``): ``init_decode_state`` then allocates a
    global page pool + per-lane page tables instead of contiguous per-lane
    slot stripes (repro.core.kvcache.PagedAttnCache).

    ``kv_dtype``/``scale_granularity``/``hot_pages`` carry the engine's
    resolved ``configs.base.QuantSpec``: ``"int8"`` pools store per-page
    symmetric-quantized K̂/V with f32 scales beside the page table, and
    ``hot_pages > 0`` adds a write-through full-precision overlay for
    that many hot-resident pages (mixed precision)."""

    page_size: int
    num_pages: int
    kv_dtype: str = "bf16"                # bf16 | int8
    scale_granularity: str = "page_head"  # page_head | page
    hot_pages: int = 0


class LM:
    """Base class: subclasses implement the per-family wiring.

    All methods are pure functions of (params, inputs) and jit-compatible;
    ``self`` only carries the static config.
    """

    def __init__(self, cfg: ModelConfig):
        cfg.validate()
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.param_dtype = jnp.dtype(cfg.param_dtype)
        # mesh-native serving: DecodeState-shaped pytree of NamedShardings
        # (None = single-device; see set_state_shardings)
        self._state_shardings = None
        # block-paged serving: PagingSpec or None (see enable_paging)
        self._paging: Optional[PagingSpec] = None

    # -- block-paged serving ------------------------------------------
    #: families that implement the paged decode-state layout
    supports_paging = False

    def enable_paging(self, spec: Optional[PagingSpec]) -> None:
        """Install (or clear) the paged cache geometry. While installed,
        ``init_decode_state`` returns the page-pool layout and the paged
        lane-surgery APIs (``graft_paged`` / ``prefill_with_prefix`` /
        ``reset_lane``) become the admission path."""
        if spec is not None and not self.supports_paging:
            raise NotImplementedError(
                f"family {self.cfg.family!r} does not support the paged "
                "KV cache (dense-transformer families only)")
        self._paging = spec

    @property
    def paging(self) -> Optional[PagingSpec]:
        return self._paging

    def graft_paged(self, state: DecodeState, req_state: DecodeState,
                    lane: jax.Array, num_slots: int) -> DecodeState:
        """Copy logical slots [0, num_slots) of a B=1 contiguous prefill
        cache into ``lane``'s pages of a paged multi-lane state."""
        raise NotImplementedError

    def prefill_with_prefix(self, params, batch, state: DecodeState,
                            lane: jax.Array, prefix_len: jax.Array,
                            aqua_proj: Optional[jax.Array] = None,
                            select_q_blk: Optional[int] = None
                            ) -> Tuple[jax.Array, DecodeState]:
        """Prefill only the *tail* of a request whose page-aligned prompt
        prefix is already mapped into ``lane`` (prefix sharing): tail
        queries attend to the shared prefix K/V read from the pool, and
        only the tail's K/V is written (into private pages)."""
        raise NotImplementedError

    def prefill_chunk(self, params, batch, state: DecodeState,
                      lane: jax.Array, prefix_len: jax.Array,
                      aqua_proj: Optional[jax.Array] = None,
                      select_q_blk: Optional[int] = None
                      ) -> Tuple[jax.Array, DecodeState]:
        """Advance ``lane``'s cache by one prefill chunk: the chunk's
        queries attend to everything the lane already holds in logical
        slots ``[0, prefix_len)`` (earlier chunks — or a shared prefix —
        of the same prompt) plus themselves, and only the chunk's K/V is
        written, starting at slot ``prefix_len``. Returns next-token
        logits for the chunk's last valid row (meaningful on the final
        chunk) and the updated state. ``select_q_blk`` (static) switches
        the AQUA dim-block selection to the block-sparse kernel's
        per-tile aggregation so chunked admissions reproduce the
        monolithic kernel's selection (cursors must be multiples of it).
        Families whose decode state is not a slot cache (recurrent
        state) cannot resume mid-prompt and keep monolithic admission
        (see ``core.dispatch``)."""
        raise NotImplementedError

    # -- mesh-native serving ------------------------------------------
    def set_state_shardings(self, shardings) -> None:
        """Install decode-state shardings (a DecodeState-shaped pytree of
        ``NamedSharding`` leaves, or None to clear). While installed, the
        lane-surgery APIs re-constrain their results, so a B=1 prefill
        graft into a sharded multi-lane state stays on the mesh — GSPMD
        sees an explicit anchor instead of inferring (and possibly
        resharding) through the scatter, and nothing round-trips the host.
        Constraints apply under jit; the serving engine only grafts inside
        its jitted admission step."""
        self._state_shardings = shardings

    def constrain_state(self, state: DecodeState) -> DecodeState:
        if self._state_shardings is None:
            return state
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            state, self._state_shardings)

    # -- required API -------------------------------------------------
    def init(self, rng: jax.Array):
        raise NotImplementedError

    def forward(self, params, batch: Dict[str, jax.Array],
                aqua_proj: Optional[jax.Array] = None, capture: bool = False):
        """Full-sequence logits (B, S, V) [, aux]."""
        raise NotImplementedError

    def init_decode_state(self, batch_size: int, max_seq: int) -> DecodeState:
        raise NotImplementedError

    def prefill(self, params, batch, max_seq: int,
                aqua_proj: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, DecodeState]:
        raise NotImplementedError

    def decode_step(self, params, state: DecodeState, tokens: jax.Array,
                    aqua_proj: Optional[jax.Array] = None,
                    write_mask: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, DecodeState]:
        """tokens: (B,) int32 -> (logits (B, V), new state).

        ``write_mask`` (B,) bool, when supported by the family, freezes
        masked-off rows' cache state (inactive scheduler lanes ride the
        batched step without mutating their lane).
        """
        raise NotImplementedError

    # -- lane surgery (continuous-batching serving) -------------------
    #
    # A *lane* is one batch row of a DecodeState. Every stacked-layer
    # leaf in this framework carries layers at axis 0 and batch at axis 1
    # ((L, B, ...)); model-level extras carry batch at axis 1 as well
    # (e.g. whisper's cross K/V (L, B, S, KV, D)), so lane surgery is
    # uniform pytree indexing. Families that break this invariant must
    # override these methods.

    def insert_lane(self, state: DecodeState, req_state: DecodeState,
                    lane: jax.Array) -> DecodeState:
        """Graft a single-request (B=1) decode state into batch row
        ``lane`` of a multi-lane state. Overwrites the lane completely —
        K/V slots, positions, count, and H2O ``acc_score`` (and AQUA
        dim-sliced K lanes ride along: the leaves are already projected/
        sliced identically on both sides since shapes derive from the same
        config + max_seq). jit-safe with a traced ``lane``; when state
        shardings are installed the grafted state is re-constrained to
        them (sharding-preserving lane surgery)."""
        lane_set = lambda dst, src: dst.at[:, lane].set(src[:, 0])
        return self.constrain_state(DecodeState(
            layers=jax.tree.map(lane_set, state.layers, req_state.layers),
            extra=jax.tree.map(lane_set, state.extra, req_state.extra),
        ))

    def reset_lane(self, state: DecodeState, lane: jax.Array,
                   max_seq: int) -> DecodeState:
        """Return ``state`` with batch row ``lane`` restored to the
        freshly-initialized (empty-cache) condition."""
        return self.insert_lane(state, self.init_decode_state(1, max_seq),
                                lane)

    def prefill_into(self, params, batch, max_seq: int, state: DecodeState,
                     lane: jax.Array,
                     aqua_proj: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, DecodeState]:
        """Prefill one request (batch size 1, optionally ragged via
        ``batch["lengths"]``) and graft its cache into ``lane`` of an
        occupied multi-lane state. Returns (next-token logits (1, V),
        updated lanes state)."""
        logits, req_state = self.prefill(params, batch, max_seq, aqua_proj)
        return logits, self.insert_lane(state, req_state, lane)

    @staticmethod
    def freeze_rows(new_state: DecodeState, old_state: DecodeState,
                    write_mask: jax.Array, batch_axis: int = 1
                    ) -> DecodeState:
        """Keep ``old_state`` for rows where ``write_mask`` is False.

        State-level fallback for families whose decode step rewrites the
        whole (small) recurrent state anyway; attention caches use the
        targeted per-slot masking in ``kvcache.insert`` instead (a full
        cache-sized ``where`` would double decode HBM traffic)."""
        def merge(new, old):
            shape = [1] * new.ndim
            shape[batch_axis] = write_mask.shape[0]
            return jnp.where(write_mask.reshape(shape), new, old)
        return DecodeState(
            layers=jax.tree.map(merge, new_state.layers, old_state.layers),
            extra=jax.tree.map(merge, new_state.extra, old_state.extra))

    # -- provided -----------------------------------------------------
    def loss(self, params, batch: Dict[str, jax.Array]):
        from repro.models.layers import cross_entropy
        logits = self.forward(params, batch)
        if isinstance(logits, tuple):
            logits, aux = logits
        else:
            aux = {}
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        l = cross_entropy(logits, labels, mask)
        if "aux_loss" in aux:
            l = l + aux["aux_loss"]
        return l, {"ce": l}
