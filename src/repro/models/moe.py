"""Mixture-of-Experts FFN — blocked GShard-style dispatch.

Covers olmoe-1b-7b (64e top-8) and qwen2-moe-a2.7b (60e top-4 + shared).

Tokens are processed in fixed blocks of ``BLOCK`` tokens; each block routes
its tokens into per-expert capacity buffers with a one-hot dispatch einsum.
Why blocked: the dispatch tensor is (T, G, E, C) with C ∝ G/E, so its size
is N·(cap_factor·K)·G — *independent of E* and linear in the block size —
and it shards perfectly under pjit: token-blocks T over the data axes,
experts E over the model axis (EP). A global-sort (megablocks) dispatch was
tried first and rejected: global argsort does not partition, and GSPMD
all-gathers the full token stream (460 GB/device at train_4k — see
EXPERIMENTS.md §Perf log).

When E doesn't divide the model axis (qwen2-moe's 60) the sharding rules
fall back to expert-ff tensor parallelism.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

BLOCK = 128  # tokens per dispatch block


def init_moe_ffn(rng, cfg: ModelConfig, dtype) -> dict:
    m = cfg.moe
    k1, k2, k3, k4, k5, k6 = jax.random.split(rng, 6)
    e, dm, f = m.num_experts, cfg.d_model, m.expert_ff
    std_in, std_out = dm ** -0.5, f ** -0.5
    p = {
        "router": jax.random.normal(k1, (dm, e), jnp.float32) * std_in,
        "w1": jax.random.normal(k2, (e, dm, f), dtype) * std_in,
        "w3": jax.random.normal(k3, (e, dm, f), dtype) * std_in,
        "w2": jax.random.normal(k4, (e, f, dm), dtype) * std_out,
    }
    if m.num_shared > 0:
        sf = m.expert_ff * m.num_shared
        p["shared"] = L.init_mlp(k5, dm, sf, gated=True, dtype=dtype)
        p["shared_gate"] = jax.random.normal(k6, (dm, 1), dtype) * std_in
    return p


def blocked_dispatch(gates: jax.Array, top_k: int, capacity: int
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """gates: (T, G, E) router probs per token block.

    Returns dispatch (T,G,E,C) 0/1, combine (T,G,E,C) f32, aux loss."""
    t, g, e = gates.shape
    topw, topi = jax.lax.top_k(gates, top_k)                # (T,G,K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    counts = jnp.zeros((t, e), jnp.int32)
    dispatch = jnp.zeros((t, g, e, capacity), jnp.bfloat16)
    combine = jnp.zeros((t, g, e, capacity), jnp.float32)
    for j in range(top_k):
        oh = jax.nn.one_hot(topi[..., j], e, dtype=jnp.int32)   # (T,G,E)
        pos = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]  # (T,G,E)
        mypos = (oh * pos).sum(-1)                               # (T,G)
        keep = (mypos < capacity)
        pos_oh = jax.nn.one_hot(mypos, capacity, dtype=jnp.float32)
        d_j = (oh.astype(jnp.float32)[..., None] * pos_oh[..., None, :]
               * keep[..., None, None].astype(jnp.float32))
        dispatch = dispatch + d_j.astype(jnp.bfloat16)
        combine = combine + d_j * topw[..., j][..., None, None]
        counts = counts + oh.sum(axis=1)
    me = gates.mean(axis=(0, 1))
    ce = jax.nn.one_hot(topi[..., 0], e).mean(axis=(0, 1))
    aux = e * jnp.sum(me * ce)
    return dispatch, combine, aux


def moe_ffn(cfg: ModelConfig, p: dict, x: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, M) -> (y, load-balance aux loss)."""
    m = cfg.moe
    b, s, dm = x.shape
    n = b * s
    g = min(BLOCK, n)
    pad = (-n) % g
    xf = x.reshape(n, dm)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    t = xf.shape[0] // g
    xb = xf.reshape(t, g, dm)

    gates = jax.nn.softmax(
        xb.astype(jnp.float32) @ p["router"], axis=-1)       # (T,G,E)
    capacity = max(m.top_k,
                   int(m.capacity_factor * m.top_k * g / m.num_experts) + 1)
    dispatch, combine, aux = blocked_dispatch(gates, m.top_k, capacity)

    d = dispatch.astype(x.dtype)
    ein = jnp.einsum("tgec,tgm->tecm", d, xb)                # (T,E,C,M)
    h = jax.nn.silu(jnp.einsum("tecm,emf->tecf", ein,
                               p["w1"].astype(x.dtype)))
    h = h * jnp.einsum("tecm,emf->tecf", ein, p["w3"].astype(x.dtype))
    eout = jnp.einsum("tecf,efm->tecm", h, p["w2"].astype(x.dtype))
    y = jnp.einsum("tgec,tecm->tgm", combine.astype(x.dtype), eout)
    y = y.reshape(-1, dm)[:n]

    if m.num_shared > 0:
        g_sh = jax.nn.sigmoid(xf[:n] @ p["shared_gate"].astype(x.dtype))
        y = y + g_sh * L.mlp(p["shared"], xf[:n], "silu")
    return y.reshape(b, s, dm), aux
