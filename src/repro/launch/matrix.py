"""README backend×mesh dispatch-matrix generator.

Renders the "Backends × mesh" support table in README.md straight from
:func:`repro.core.dispatch.resolve_dispatch_plan`, so the documented
matrix can never drift from what the engine actually dispatches: each
cell is a real resolved :class:`DispatchPlan` for that backend × cache
layout on a reference 2x2 data×model mesh (geometry that divides — the
README rows describe the *capability*, not a particular device count).
Resolution only reads mesh axis names/sizes, so an ``AbstractMesh``
suffices and no devices are required.

Regenerate the README block with::

    PYTHONPATH=src python -m repro.launch.matrix --readme README.md

``tests/test_dispatch_plan.py`` keeps the committed block golden against
this generator.
"""
from __future__ import annotations

import argparse
import dataclasses
import re

from jax.sharding import AbstractMesh

from repro.configs.base import (AquaConfig, AttentionConfig, CacheSpec,
                                QuantSpec, ServingConfig, SparsitySpec)
from repro.core.dispatch import resolve_dispatch_plan

BEGIN = "<!-- dispatch-matrix:begin (repro.launch.matrix — do not edit) -->"
END = "<!-- dispatch-matrix:end -->"

# Reference geometry: axis extents that divide (4 lanes over data=2,
# kv=2 over model=2, page_size a KERNEL_PAGE_MULTIPLE multiple).
_ATT = AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16)
_SERVING = ServingConfig(max_lanes=4, max_seq=64)

# (README row label, backend key, aqua config)
_ROWS = (
    ("dense-jnp", "dense-jnp", None),
    ("flash", "flash", None),
    ("aqua-masked-dense", "aqua-masked-dense",
     AquaConfig(k_ratio=0.75, block_dims=1)),
    ("aqua-block-sparse", "aqua-block-sparse",
     AquaConfig(k_ratio=0.5, block_dims=8)),
)


def _cell(plan) -> str:
    if plan.mesh_native:
        if plan.quantization != "none":
            return "shard_mapped Pallas kernel (scale-folded int8)"
        return "shard_mapped Pallas kernel"
    # the structured reasons are the REASON_* constants; the first one is
    # the highest-priority explanation in check order
    return f"shard_map/jnp reference ({plan.reasons[0]})"


def _chunk_cell(plan) -> str:
    if plan.chunked_prefill:
        return "interleaved (PREFILLING lanes)"
    return f"monolithic admit ({plan.chunked_reasons[0]})"


def _token_cell(plan) -> str:
    if plan.token_sparsity == "hierarchical":
        return "hierarchical (page-granular stage 1)"
    return f"none ({plan.token_reasons[0]})"


def generate_matrix() -> str:
    """The README table (markdown, BEGIN/END markers included)."""
    mesh = AbstractMesh((("data", 2), ("model", 2)))
    lines = [
        BEGIN,
        "| backend | contiguous cache @ mesh | paged cache @ mesh "
        "| int8 paged cache @ mesh | chunked prefill @ budget "
        "| token sparsity @ keep 0.5 |",
        "|---|---|---|---|---|---|",
    ]
    layouts = (
        (CacheSpec(), QuantSpec()),
        (CacheSpec(page_size=8), QuantSpec()),
        (CacheSpec(page_size=8), QuantSpec(kv_dtype="int8")),
    )
    for label, backend, aqua in _ROWS:
        att = dataclasses.replace(_ATT, backend=backend)
        cells = []
        for cache, quant in layouts:
            serving = dataclasses.replace(_SERVING, cache=cache,
                                          quant=quant)
            plan = resolve_dispatch_plan(attention=att, aqua=aqua,
                                         serving=serving, mesh=mesh)
            cells.append(_cell(plan))
        # chunked-prefill admissibility is cache-layout independent; the
        # reference budget is one block-sparse q-chunk tile (128), the
        # geometry the REASON_CHUNK_GEOMETRY predicate requires
        serving = dataclasses.replace(_SERVING, prefill_budget_tokens=128)
        plan = resolve_dispatch_plan(attention=att, aqua=aqua,
                                     serving=serving, mesh=mesh)
        cells.append(_chunk_cell(plan))
        # stage-1 token sparsity needs a paged pool; every backend honors
        # it (a *selection* mode: kernel and reference paths stream/mask
        # the same participating-page set, so it is not a dispatch fork)
        serving = dataclasses.replace(
            _SERVING, cache=CacheSpec(page_size=8),
            sparsity=SparsitySpec(page_keep_ratio=0.5))
        plan = resolve_dispatch_plan(attention=att, aqua=aqua,
                                     serving=serving, mesh=mesh)
        cells.append(_token_cell(plan))
        lines.append(f"| `{label}` | {cells[0]} | {cells[1]} | {cells[2]} "
                     f"| {cells[3]} | {cells[4]} |")
    lines.append(END)
    return "\n".join(lines)


def embed(readme_text: str) -> str:
    """Replace the BEGIN..END block in ``readme_text`` with a freshly
    generated matrix (the markers must already exist)."""
    block = generate_matrix()
    pattern = re.compile(re.escape(BEGIN) + r".*?" + re.escape(END),
                         re.DOTALL)
    if not pattern.search(readme_text):
        raise ValueError("README has no dispatch-matrix markers")
    return pattern.sub(lambda _: block, readme_text)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--readme", default=None,
                    help="rewrite the marked block in this file in place "
                         "(default: print the table to stdout)")
    args = ap.parse_args(argv)
    if args.readme is None:
        print(generate_matrix())
        return
    with open(args.readme) as f:
        text = f.read()
    with open(args.readme, "w") as f:
        f.write(embed(text))
    print(f"[matrix] rewrote dispatch matrix in {args.readme}")


if __name__ == "__main__":
    main()
