# intentionally empty: launch modules must control jax initialization order
# (dryrun.py sets XLA_FLAGS before importing jax).
