"""Training driver: pjit train step, gradient accumulation, checkpointing
with auto-resume, optional int8 error-feedback gradient compression.

CLI:  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
          --steps 100 --reduced   (CPU-scale run)
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import DataConfig, add_frontend_inputs, make_batch
from repro.models import build_model
from repro.optim import adamw
from repro.optim.schedule import cosine_with_warmup


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: adamw.AdamWState
    step: jax.Array


def make_train_step(model, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    Gradient accumulation over ``tcfg.microbatches`` microbatches via scan
    (batch leading dim must divide)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state: TrainState, batch):
        mb = tcfg.microbatches
        if mb > 1:
            split = jax.tree.map(
                lambda a: a.reshape(mb, a.shape[0] // mb, *a.shape[1:]),
                batch)

            def acc_fn(carry, micro):
                (l, g) = jax.value_and_grad(
                    lambda p: loss_fn(p, micro)[0])(state.params)
                cl, cg = carry
                return (cl + l / mb,
                        jax.tree.map(lambda a, b: a + b / mb, cg, g)), None
            zero_g = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32),
                                  state.params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (0.0, zero_g), split)
        else:
            (loss, _), grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch), has_aux=True)(state.params)
        lr = cosine_with_warmup(state.step, tcfg)
        params, opt = adamw.update(state.params, grads, state.opt, lr, tcfg)
        metrics = {"loss": loss, "lr": lr,
                   "grad_norm": adamw.global_norm(grads)}
        return TrainState(params=params, opt=opt, step=state.step + 1), metrics

    return train_step


class Trainer:
    def __init__(self, mcfg: ModelConfig, tcfg: TrainConfig,
                 dcfg: DataConfig, ckpt_dir: Optional[str] = None,
                 mesh=None, donate: bool = True):
        self.mcfg, self.tcfg, self.dcfg = mcfg, tcfg, dcfg
        self.model = build_model(mcfg)
        self.mesh = mesh
        self.ckpt = (CheckpointManager(ckpt_dir, keep=tcfg.keep_checkpoints)
                     if ckpt_dir else None)
        step_fn = make_train_step(self.model, tcfg)
        kw = {}
        if donate:
            kw["donate_argnums"] = (0,)
        if mesh is not None:
            from repro.distributed import sharding as sh
            self._make_shardings = lambda state: jax.tree_util.tree_map_with_path(
                lambda p, a: sh.NamedSharding(
                    mesh, sh.param_pspec(p, a.shape, mesh)), state)
        self._step_fn = jax.jit(step_fn, **kw)

    def init_state(self, seed: int = 0) -> TrainState:
        params = self.model.init(jax.random.PRNGKey(seed))
        return TrainState(params=params, opt=adamw.init(params),
                          step=jnp.zeros((), jnp.int32))

    def restore_or_init(self) -> TrainState:
        state = self.init_state(self.tcfg.seed)
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            state, step = self.ckpt.restore(None, state)
            print(f"[train] resumed from step {step}")
        return state

    def run(self, steps: int, log_every: int = 10):
        state = self.restore_or_init()
        start = int(state.step)
        t0 = time.time()
        losses = []
        for i in range(start, start + steps):
            batch = make_batch(self.dcfg, i)
            batch = add_frontend_inputs(batch, self.mcfg, i)
            state, metrics = self._step_fn(state, batch)
            losses.append(float(metrics["loss"]))
            if (i + 1) % log_every == 0:
                dt = (time.time() - t0) / max(i + 1 - start, 1)
                print(f"step {i+1} loss={losses[-1]:.4f} "
                      f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f} ms/step")
            if (self.ckpt is not None
                    and (i + 1) % self.tcfg.checkpoint_every == 0):
                self.ckpt.save(i + 1, state, blocking=False)
        if self.ckpt is not None:
            self.ckpt.wait()
            self.ckpt.save(start + steps, state, blocking=True)
        return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale reduced config")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    mcfg = reduced(args.arch) if args.reduced else get_config(args.arch)
    tcfg = TrainConfig(total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 10),
                       microbatches=args.microbatches,
                       checkpoint_every=max(10, args.steps // 4))
    dcfg = DataConfig(vocab_size=mcfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    trainer = Trainer(mcfg, tcfg, dcfg, ckpt_dir=args.ckpt_dir)
    _, losses = trainer.run(args.steps)
    print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
