"""Serving driver: calibrate-once, then serve a mixed-traffic trace.

Drives the continuous-batching engine over a Poisson arrival trace
(exponential inter-arrival times in decode-step units, mixed prompt
lengths) and reports throughput + lane occupancy. ``--rectangular``
falls back to the old fixed-batch ``ServeEngine`` drive for comparison.

``--mesh DxM`` serves mesh-native on a data×model device mesh (decode
lanes data-parallel, params/KV cache tensor-parallel; Pallas backends
run shard_mapped when the axis extents divide the mesh); ``--verify``
re-serves the same trace single-device and asserts token-identical
outputs (the multi-device CI acceptance check) — and, when a Pallas
backend should serve shard_mapped, additionally asserts that no mesh
kernel fallback fired (the kernel path really ran on the mesh).

CLI (CPU-scale):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --k-ratio 0.75 --h2o-ratio 0.5 --requests 8 --lanes 4

  # 4x2 data×model mesh on 8 forced host devices, verified vs 1-device
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --requests 8 --lanes 8 --mesh 4x2 --verify
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import numpy as np

import jax

from repro.configs import get_config, reduced
from repro.configs.base import (AquaConfig, CacheSpec, QuantSpec,
                                ServingConfig, SparsitySpec)
from repro.core.calibration import calibrate, identity_projections
from repro.data.pipeline import DataConfig, add_frontend_inputs, \
    calibration_batches, make_batch
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, ServeEngine, \
    poisson_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--hf-checkpoint", default=None,
                    help="serve real weights: path to an HF-format "
                         "safetensors checkpoint dir (config.json + "
                         "model.safetensors[.index.json]); overrides "
                         "--arch/--reduced — the architecture is read "
                         "from config.json (see checkpoint.hf)")
    ap.add_argument("--calibration-corpus", default=None,
                    help="tokenized corpus file for the offline SVD "
                         "calibration (.npy/.npz ids or .txt byte-level; "
                         "see data.pipeline.load_token_corpus); default "
                         "is the synthetic LCG language")
    ap.add_argument("--projections", default=None,
                    help="AquaProjections .npz artifact path: load it if "
                         "it exists, else calibrate and save there "
                         "(skip recalibration across serve runs)")
    ap.add_argument("--k-ratio", type=float, default=0.75)
    ap.add_argument("--s-ratio", type=float, default=0.0)
    ap.add_argument("--h2o-ratio", type=float, default=1.0)
    ap.add_argument("--block-dims", type=int, default=1)
    ap.add_argument("--prefill-q-blk", type=int, default=None,
                    help="block-sparse prefill kernel q-chunk tile (one "
                         "dim-block selection per tile); a chunked-prefill "
                         "budget must be a multiple of it")
    ap.add_argument("--no-aqua", action="store_true")
    ap.add_argument("--backend", default=None,
                    help="attention backend override (see core.attention)")
    # trace shape
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--mean-interarrival", type=float, default=2.0,
                    help="Poisson trace: mean inter-arrival (decode steps)")
    ap.add_argument("--prompt-lens", default="8,16,24",
                    help="comma-separated mixed prompt lengths")
    ap.add_argument("--steps", type=int, default=16,
                    help="max new tokens per request")
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rectangular", action="store_true",
                    help="old fixed-batch ServeEngine drive (comparison)")
    # block-paged KV cache
    ap.add_argument("--page-size", type=int, default=None,
                    help="tokens per KV-cache page: replaces per-lane "
                         "contiguous slot stripes with a global page pool "
                         "+ per-lane page tables (None = contiguous)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="page-pool size; default = lane-stripe parity "
                         "(lanes * slots / page_size) — set lower to "
                         "realize the HBM win (admissions then queue on "
                         "free pages)")
    ap.add_argument("--no-prefix-share", action="store_true",
                    help="disable prompt prefix page sharing")
    ap.add_argument("--kv-dtype", default="bf16", choices=("bf16", "int8"),
                    help="paged K̂/V pool storage dtype: 'int8' stores "
                         "per-page symmetric-quantized pools with f32 "
                         "scale metadata beside the page table (requires "
                         "--page-size); decode folds the scales into the "
                         "Pallas kernel's softmax scale — no dequant pass")
    ap.add_argument("--scale-granularity", default="page_head",
                    choices=("page_head", "page"),
                    help="int8 scale granularity: one scale per "
                         "(page, kv head) or one per page")
    ap.add_argument("--hot-frac", type=float, default=0.0,
                    help="fraction of the pool kept as full-precision hot "
                         "residents (H2O score policy; mixed precision "
                         "serves on the reference path, not the kernel)")
    # hierarchical (two-stage) token sparsity
    ap.add_argument("--page-keep-ratio", type=float, default=1.0,
                    help="hierarchical AQUA: fraction of each lane's pages "
                         "participating in decode attention (stage-1 "
                         "page-granular token sparsity ranked by H2O page "
                         "mass; stage 2 is the |q̂| dim-block top-k). "
                         "Requires --page-size; 1.0 = every page (exactly "
                         "the plain paged kernel)")
    ap.add_argument("--pin-recent-pages", type=int, default=2,
                    help="hierarchical: trailing pages per lane always "
                         "participating (probe token + local window stay "
                         "exact)")
    # chunked-prefill/decode interleaving
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="interleave admissions with decode: at most this "
                         "many prefill tokens run between consecutive "
                         "decode steps (None = monolithic admission; the "
                         "engine falls back with an attributed reason when "
                         "the geometry/policy can't chunk — see "
                         "dispatch_plan().chunked_reasons)")
    ap.add_argument("--itl-slo-ms", type=float, default=None,
                    help="report the fraction of inter-token gaps above "
                         "this wall-clock threshold (SLO miss rate)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="prepend a fixed random prefix of this length to "
                         "every trace prompt (prefix-sharing demo/CI)")
    ap.add_argument("--mesh", default="",
                    help="serving mesh 'DATAxMODEL' (e.g. 4x2) or "
                         "'PODxDATAxMODEL'; empty/1x1 = single device")
    ap.add_argument("--verify", action="store_true",
                    help="re-serve the trace single-device and require "
                         "token-identical outputs (exits 1 on mismatch)")
    ap.add_argument("--expect-kernel-mesh", action="store_true",
                    help="require the shard_mapped Pallas kernel path: fail "
                         "unless the engine dispatches the block-sparse "
                         "kernels natively on the mesh (guards the CI "
                         "acceptance drive against a dispatch-predicate "
                         "regression silently serving the jnp reference)")
    args = ap.parse_args()

    if args.hf_checkpoint is not None:
        from repro.checkpoint.hf import config_from_hf, load_hf_checkpoint
        cfg = config_from_hf(args.hf_checkpoint)
        print(f"[serve] HF checkpoint {args.hf_checkpoint}: "
              f"{cfg.name} ({cfg.num_layers}L d{cfg.d_model})")
    else:
        cfg = reduced(args.arch) if args.reduced else get_config(args.arch)
    aqua = None
    if not args.no_aqua and cfg.attention is not None:
        aqua = AquaConfig(k_ratio=args.k_ratio, s_ratio=args.s_ratio,
                          h2o_ratio=args.h2o_ratio,
                          block_dims=args.block_dims)
        if args.prefill_q_blk is not None:
            aqua = dataclasses.replace(aqua,
                                       prefill_q_blk=args.prefill_q_blk)
    cfg = dataclasses.replace(cfg, aqua=aqua)

    model = build_model(cfg)
    if args.hf_checkpoint is not None:
        params = load_hf_checkpoint(args.hf_checkpoint, cfg)
    else:
        params = model.init(jax.random.PRNGKey(0))

    proj = None
    if aqua is not None and args.projections is not None \
            and os.path.exists(args.projections):
        from repro.core.calibration import load_projections
        proj = load_projections(args.projections)
        print(f"[serve] loaded AQUA projections from {args.projections}")
    elif aqua is not None:
        src = args.calibration_corpus or "synthetic LCG"
        print(f"[serve] offline AQUA calibration for {cfg.name} "
              f"(corpus: {src}) ...")
        if cfg.family == "hybrid":
            # capture path collects only attention layers
            n_attn = model.num_attn_layers
            proj = identity_projections(n_attn, cfg.attention.num_kv_heads,
                                        cfg.attention.head_dim)

        def fwd_cap(p, batch):
            _, aux = model.forward(p, batch, capture=True)
            return aux
        proj = calibrate(fwd_cap, params,
                         calibration_batches(
                             cfg, num_batches=2, batch=2, seq=32,
                             corpus_path=args.calibration_corpus),
                         cfg) \
            if cfg.family != "hybrid" else proj
        if args.projections is not None:
            from repro.core.calibration import save_projections
            save_projections(args.projections, proj)
            print(f"[serve] saved AQUA projections to {args.projections}")

    if args.rectangular:
        _drive_rectangular(cfg, params, proj, args)
        return

    from repro.launch.mesh import make_serving_mesh, parse_mesh_spec
    mesh_spec = parse_mesh_spec(args.mesh)
    mesh = None
    if mesh_spec is not None:
        mesh = make_serving_mesh(*mesh_spec)
        print(f"[serve] mesh {dict(mesh.shape)} over {mesh.size} "
              f"{mesh.devices.flat[0].platform} devices")
    scfg = ServingConfig(max_lanes=args.lanes, max_seq=args.max_seq,
                         max_new_tokens=args.steps,
                         temperature=args.temperature,
                         prefill_budget_tokens=args.prefill_budget,
                         cache=CacheSpec(
                             page_size=args.page_size,
                             num_pages=args.pool_pages,
                             prefix_sharing=not args.no_prefix_share),
                         quant=QuantSpec(
                             kv_dtype=args.kv_dtype,
                             scale_granularity=args.scale_granularity,
                             hot_resident_fraction=args.hot_frac),
                         sparsity=SparsitySpec(
                             page_keep_ratio=args.page_keep_ratio,
                             pin_recent_pages=args.pin_recent_pages))
    eng = ContinuousBatchingEngine(cfg, params, proj, serving=scfg,
                                   backend=args.backend, mesh=mesh)
    plan = eng.dispatch_plan()
    if args.prefill_budget is not None and not plan.chunked_prefill:
        print("[serve] chunked prefill OFF (monolithic admission): "
              f"{'; '.join(plan.chunked_reasons)}")
        if args.verify:
            # CI drives a budget to pin the interleaved path; a predicate
            # regression silently serving monolithic must fail loudly
            print("[serve] VERIFY FAILED: --prefill-budget requested but "
                  "the engine planned monolithic admission")
            raise SystemExit(1)
    if args.page_keep_ratio < 1.0 and plan.token_sparsity != "hierarchical":
        print("[serve] hierarchical token sparsity OFF (all pages "
              f"participate): {'; '.join(plan.token_reasons)}")
        if args.verify:
            # CI pins the hierarchical path with a ratio; a predicate
            # regression silently attending every page must fail loudly
            print("[serve] VERIFY FAILED: --page-keep-ratio requested but "
                  "the engine planned full page participation")
            raise SystemExit(1)
    if args.expect_kernel_mesh and not plan.mesh_native:
        # independent of the engine's own dispatch decision: the caller
        # (CI) declares the kernel path is REQUIRED for this geometry, so
        # a predicate regression fails loudly instead of silently serving
        # the masked-dense reference
        print("[serve] EXPECT-KERNEL FAILED: engine did not plan the "
              "kernel-native mesh path "
              f"(backend={plan.backend!r} layout={plan.cache_layout}); "
              f"reasons: {'; '.join(plan.reasons)}")
        raise SystemExit(1)
    prompt_lens = tuple(int(x) for x in args.prompt_lens.split(","))
    reqs = poisson_trace(args.requests,
                         mean_interarrival=args.mean_interarrival,
                         prompt_lens=prompt_lens,
                         max_new_tokens=args.steps,
                         vocab_size=cfg.vocab_size, seed=args.seed,
                         temperature=args.temperature)
    if args.shared_prefix_len > 0:
        pre = np.random.default_rng(args.seed + 1).integers(
            0, cfg.vocab_size, size=(args.shared_prefix_len,),
            dtype=np.int32)
        for r in reqs:
            r.tokens = np.concatenate([pre, np.asarray(r.tokens, np.int32)])
    if cfg.frontend.kind != "none":
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=1,
                          global_batch=1)
        for r in reqs:
            r.extra_inputs = {
                k: v for k, v in add_frontend_inputs(
                    {"tokens": make_batch(dcfg, 0)["tokens"]}, cfg).items()
                if k != "tokens"}

    t0 = time.time()
    finished = 0
    streamed: dict = {}
    for ev in eng.serve(reqs):
        streamed.setdefault(ev.uid, []).append(ev.token)
        if ev.finished:
            finished += 1
            print(f"[serve] request {ev.uid} done: {ev.index + 1} tokens "
                  f"({ev.finish_reason})")
    dt = time.time() - t0
    st = eng.stats
    print(f"[serve] {finished}/{len(reqs)} requests, "
          f"{st.tokens_emitted} tokens in {dt:.2f}s "
          f"({st.tokens_emitted / dt:.1f} tok/s), "
          f"{st.decode_steps} decode steps, "
          f"mean lane occupancy {st.mean_occupancy:.2f}/{args.lanes}")
    if st.itl_gaps:
        line = (f"[serve] inter-token latency: p50 "
                f"{st.itl_percentile(50) * 1e3:.1f}ms, p99 "
                f"{st.itl_percentile(99) * 1e3:.1f}ms, max "
                f"{st.max_itl * 1e3:.1f}ms")
        if args.itl_slo_ms is not None:
            line += (f", SLO>{args.itl_slo_ms:g}ms miss rate "
                     f"{st.slo_miss_rate(args.itl_slo_ms / 1e3):.3f}")
        print(line)
    if args.prefill_budget is not None and plan.chunked_prefill:
        print(f"[serve] chunked prefill: {st.chunked_admissions} admissions "
              f"interleaved over {st.prefill_chunks} chunk steps "
              f"(budget {args.prefill_budget} tokens/step)")
    print(f"[serve] KV cache bytes @ {args.lanes} lanes: "
          f"{eng.cache_bytes():,}")
    if eng.paged:
        from repro.serving.engine import decode_state_bytes
        pool = eng.page_pool
        num_pages, per_lane, ps = eng.pool_geometry
        stripe_bytes = decode_state_bytes(build_model(cfg), args.lanes,
                                          args.max_seq)
        ratio = eng.cache_bytes() / stripe_bytes
        print(f"[serve] page pool: {num_pages} pages x {ps} tokens "
              f"(lane-stripe parity {per_lane * args.lanes}), "
              f"peak {pool.peak_in_use} in use, "
              f"mean utilization {pool.mean_utilization:.2f}")
        print(f"[serve] prefix sharing: {pool.prefix_hits} admissions "
              f"reused a shared prefix, {pool.tokens_saved} prefill "
              f"tokens saved")
        print(f"[serve] pool bytes vs lane-stripe bytes: "
              f"{eng.cache_bytes():,} / {stripe_bytes:,} = {ratio:.2f}x")
        if args.verify and num_pages < per_lane * args.lanes \
                and eng.cache_bytes() >= stripe_bytes:
            print("[serve] VERIFY FAILED: paged pool is smaller than "
                  "lane-stripe parity but does not report fewer cache "
                  "bytes")
            raise SystemExit(1)
        if (args.verify and args.shared_prefix_len > 0
                and not args.no_prefix_share and args.requests >= 2
                and pool.prefix_hits < 1):
            print("[serve] VERIFY FAILED: every prompt carries the same "
                  f"{args.shared_prefix_len}-token prefix but no "
                  "admission reused shared prefix pages")
            raise SystemExit(1)
        if eng.kept_pages is not None:
            kp, npl = eng.kept_pages, per_lane
            print(f"[serve] hierarchical: {kp}/{npl} pages per lane "
                  f"participate in decode (keep ratio "
                  f"{args.page_keep_ratio:g}, {args.pin_recent_pages} "
                  "recent pinned)")
            # numpy page-ranking oracle vs the jit stage-1 selection on
            # the terminal engine state — --verify pins that the table the
            # kernels scalar-prefetched is the one the reference ranking
            # math produces
            if args.verify:
                import jax as _jax
                from repro.core import kvcache as kvc
                from repro.core import selection
                stacked = [x for x in _jax.tree_util.tree_leaves(
                    eng.last_state,
                    is_leaf=lambda t: isinstance(t, kvc.PagedAttnCache))
                    if isinstance(x, kvc.PagedAttnCache)]
                # model decode state stacks layers into one cache (leading
                # L axis on every leaf); unstack to per-layer views
                caches = []
                for c in stacked:
                    if c.page_table.ndim == 2:
                        caches.append(c)
                        continue
                    for li in range(c.page_table.shape[0]):
                        caches.append((c.acc_pool[li], c.page_table[li],
                                       c.count[li]))
                bad_oracle = 0
                for c in caches:
                    acc, table, count = (
                        (c.acc_pool, c.page_table, c.count)
                        if isinstance(c, kvc.PagedAttnCache) else c)
                    got = np.asarray(selection.participating_pages(
                        acc, table, count,
                        page_size=ps, kept_pages=kp,
                        pin_recent_pages=args.pin_recent_pages))
                    want = selection.reference_participating_pages(
                        acc, table, count,
                        page_size=ps, kept_pages=kp,
                        pin_recent_pages=args.pin_recent_pages)
                    bad_oracle += int(not np.array_equal(got, want))
                if bad_oracle:
                    print(f"[serve] VERIFY FAILED: jit page ranking "
                          f"diverges from the numpy oracle on "
                          f"{bad_oracle}/{len(caches)} layer caches")
                    raise SystemExit(1)
                print(f"[serve] verify: page-ranking oracle agrees on all "
                      f"{len(caches)} layer caches")
        if eng.quant_spec.quantized:
            from repro.models.base import PagingSpec
            fp_model = build_model(cfg)
            fp_model.enable_paging(PagingSpec(ps, num_pages))
            fp_bytes = decode_state_bytes(fp_model, args.lanes,
                                          args.max_seq)
            qratio = eng.cache_bytes() / fp_bytes
            print(f"[serve] quantized pool ({eng.quant_spec.kv_dtype}) "
                  f"bytes vs full-precision paged: {eng.cache_bytes():,} "
                  f"/ {fp_bytes:,} = {qratio:.2f}x")
            if args.verify and qratio >= 0.60:
                print("[serve] VERIFY FAILED: quantized pool does not "
                      "realize the memory win (expected <= 0.60x the "
                      "full-precision paged pool)")
                raise SystemExit(1)

    if ((args.verify or args.expect_kernel_mesh) and mesh is not None
            and plan.mesh_native):
        # kernel-path identity is only meaningful if the kernel actually
        # served on the mesh. `plan.mesh_native` is the engine's resolved
        # dispatch decision (backend resolves to the block-sparse kernel,
        # AQUA block + page geometry + mesh extents admit it, no
        # H2O/window policy in the way) — --expect-kernel-mesh above
        # already failed if that decision itself went wrong — so any
        # per-engine fallback event means the masked-dense reference
        # silently served instead.
        backend_name = eng.cfg.attention.backend
        events = eng.mesh_fallback_events()
        if events:
            print(f"[serve] VERIFY FAILED: backend {backend_name!r} should "
                  f"serve shard_mapped on this mesh but fell back: {events}")
            raise SystemExit(1)
        print(f"[serve] verify: backend {backend_name!r} served shard_mapped "
              "on the mesh (no kernel fallback)")

    if args.verify:
        # Token-identity reference. At greedy (temperature 0) the trace
        # re-serves on a fresh SINGLE-DEVICE engine — cross-partitioning
        # equality only holds there, since resharding the model axis
        # reorders float reductions and Gumbel sampling amplifies ulp
        # differences. At temperature > 0 each request instead re-serves
        # SOLO on a fresh same-mesh engine (empty lanes, arrival 0): that
        # checks the placement/co-tenant independence the (uid, counter)
        # RNG fold guarantees, and would catch e.g. a key folded on the
        # lane index — a batched same-trace rerun would not.
        if args.temperature > 0:
            where = "solo same-mesh"
            ref = {}
            for r in reqs:
                solo_eng = ContinuousBatchingEngine(
                    cfg, params, proj, serving=scfg, backend=args.backend,
                    mesh=mesh)
                ref.update(solo_eng.run(
                    [dataclasses.replace(r, arrival=0.0)]))
        else:
            # greedy: the reference is single-device AND contiguous, so a
            # paged drive is checked against the lane-stripe layout it
            # replaces (token-identity is exact — the gathered lane view
            # is slot-for-slot the contiguous cache). Exception: when
            # prefix sharing actually engages, a shared admission prefills
            # only its *tail* (attention.prefixed_tail_attention) — a
            # different reduction split than the contiguous engine's full
            # prompt prefill. The jnp backends reduce identically either
            # way, but a kernel-native engine full-prefills through the
            # Pallas prefill kernel, so shared-tail logits move by ulps
            # and greedy tokens can flip. Kernel-native prefix drives
            # therefore verify against the single-device *paged* engine
            # instead: the same admission paths solo, so the mesh wrap —
            # which is what --verify pins here — must be token-exact.
            # Quantized drives route the same way for a different reason:
            # int8 pools round differently than a full-precision cache by
            # construction, so only the single-device engine with the SAME
            # quantization math is a token-exact reference.
            # Hierarchical drives route like quantized ones: dropping
            # pages changes outputs vs exact attention by construction, so
            # only the single-device engine with the SAME page-ranking
            # math (scfg carries the SparsitySpec) is token-exact.
            prefix_engaged = (plan.prefix_sharing and plan.mesh_native
                              and args.shared_prefix_len > 0)
            if (prefix_engaged or plan.quantization != "none"
                    or plan.token_sparsity != "none"):
                where = ("single-device paged"
                         if plan.quantization == "none"
                         else f"single-device paged {plan.quantization}")
                if plan.token_sparsity != "none":
                    where += " hierarchical"
                ref_scfg = scfg
            else:
                where = "single-device contiguous"
                ref_scfg = dataclasses.replace(scfg, cache=CacheSpec(),
                                               quant=QuantSpec())
            # the reference always admits monolithically: a chunked drive
            # is thereby pinned against the engine it replaces — chunking
            # must change *when* work happens, never *what* is computed
            ref_scfg = dataclasses.replace(ref_scfg,
                                           prefill_budget_tokens=None)
            if args.prefill_budget is not None:
                where += " monolithic-admit"
            ref_eng = ContinuousBatchingEngine(cfg, params, proj,
                                               serving=ref_scfg,
                                               backend=args.backend)
            ref = ref_eng.run(reqs)
        bad = [uid for uid, toks in streamed.items()
               if list(ref[uid].tokens) != toks]
        if bad:
            print(f"[serve] VERIFY FAILED: outputs diverge from the "
                  f"{where} reference for uids {bad}")
            raise SystemExit(1)
        print(f"[serve] verify: all {len(streamed)} requests "
              f"token-identical to the {where} reference engine")
        if (args.prefill_budget is not None and plan.chunked_prefill
                and args.temperature == 0):
            # the point of interleaving: decode lanes never stall for a
            # whole co-tenant prefill, so the worst inter-token gap must
            # come down vs the monolithic-admit reference on the same
            # trace. Both engines re-serve WARM (every jit shape was
            # compiled by the drives above) — the first drives' gaps are
            # dominated by compilation, which the chunked engine pays
            # more of (one extra jit per chunk geometry), not by the
            # admission stalls this check pins.
            eng.run([dataclasses.replace(r) for r in reqs])
            ref_eng.run([dataclasses.replace(r) for r in reqs])
            warm_max = eng.stats.max_itl
            ref_max = ref_eng.stats.max_itl
            if warm_max >= ref_max and ref_max > 0:
                print(f"[serve] VERIFY FAILED: chunked max inter-token gap "
                      f"{warm_max * 1e3:.1f}ms is not below the "
                      f"monolithic reference's {ref_max * 1e3:.1f}ms "
                      "(warm re-drives)")
                raise SystemExit(1)
            print(f"[serve] verify: max inter-token gap "
                  f"{warm_max * 1e3:.1f}ms < monolithic "
                  f"{ref_max * 1e3:.1f}ms (warm re-drives)")


def _drive_rectangular(cfg, params, proj, args):
    """Old fixed-batch drive: every request prefills together and decodes
    in lockstep — no overlap, occupancy == 1 request-batch at a time."""
    eng = ServeEngine(cfg, params, proj, max_seq=args.max_seq,
                      backend=args.backend)
    batch_size = min(args.requests, args.lanes)
    prompt_len = int(args.prompt_lens.split(",")[0])
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=prompt_len,
                      global_batch=batch_size)
    batch = add_frontend_inputs(
        {"tokens": make_batch(dcfg, 0)["tokens"]}, cfg)
    t0 = time.time()
    res = eng.generate(batch, steps=args.steps,
                       temperature=args.temperature)
    dt = time.time() - t0
    tps = batch_size * args.steps / dt
    print(f"[serve] rectangular: generated {res.tokens.shape} tokens in "
          f"{dt:.2f}s ({tps:.1f} tok/s)")
    print(f"[serve] KV cache bytes @ batch={batch_size}: "
          f"{eng.cache_bytes(batch_size):,}")
    print("[serve] sample:", np.asarray(res.tokens[0])[:16].tolist())


if __name__ == "__main__":
    main()
