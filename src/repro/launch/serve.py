"""Serving driver: calibrate-once, serve-with-AQUA.

CLI (CPU-scale):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --k-ratio 0.75 --h2o-ratio 0.5 --steps 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import AquaConfig
from repro.core.calibration import calibrate, identity_projections
from repro.data.pipeline import DataConfig, add_frontend_inputs, \
    calibration_batches, make_batch
from repro.models import build_model
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--k-ratio", type=float, default=0.75)
    ap.add_argument("--s-ratio", type=float, default=0.0)
    ap.add_argument("--h2o-ratio", type=float, default=1.0)
    ap.add_argument("--block-dims", type=int, default=1)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--no-aqua", action="store_true")
    args = ap.parse_args()

    cfg = reduced(args.arch) if args.reduced else get_config(args.arch)
    aqua = None
    if not args.no_aqua and cfg.attention is not None:
        aqua = AquaConfig(k_ratio=args.k_ratio, s_ratio=args.s_ratio,
                          h2o_ratio=args.h2o_ratio,
                          block_dims=args.block_dims)
    cfg = dataclasses.replace(cfg, aqua=aqua)

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    proj = None
    if aqua is not None:
        print(f"[serve] offline AQUA calibration for {args.arch} ...")
        if cfg.family == "hybrid":
            # capture path collects only attention layers
            n_attn = model.num_attn_layers
            proj = identity_projections(n_attn, cfg.attention.num_kv_heads,
                                        cfg.attention.head_dim)

        def fwd_cap(p, batch):
            _, aux = model.forward(p, batch, capture=True)
            return aux
        proj = calibrate(fwd_cap, params,
                         calibration_batches(cfg, num_batches=2, batch=2,
                                             seq=32), cfg) \
            if cfg.family != "hybrid" else proj

    eng = ServeEngine(cfg, params, proj, max_seq=args.max_seq)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.prompt_len,
                      global_batch=args.batch)
    batch = add_frontend_inputs(
        {"tokens": make_batch(dcfg, 0)["tokens"]}, cfg)

    t0 = time.time()
    res = eng.generate(batch, steps=args.steps)
    dt = time.time() - t0
    tps = args.batch * args.steps / dt
    print(f"[serve] generated {res.tokens.shape} tokens in {dt:.2f}s "
          f"({tps:.1f} tok/s on CPU)")
    print(f"[serve] KV cache bytes @ batch={args.batch}: "
          f"{eng.cache_bytes(args.batch):,}")
    print("[serve] sample:", np.asarray(res.tokens[0])[:16].tolist())


if __name__ == "__main__":
    main()
