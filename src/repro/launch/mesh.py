"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16, 16) = (data, model).
    Multi-pod: 512 chips (2, 16, 16) = (pod, data, model); the "pod" axis
    is pure data parallelism across the DCN/ICI-pod boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
