"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16, 16) = (data, model).
    Multi-pod: 512 chips (2, 16, 16) = (pod, data, model); the "pod" axis
    is pure data parallelism across the DCN/ICI-pod boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_serving_mesh(shape, axes=("data", "model")):
    """Mesh over the first prod(shape) available devices (serving engine).

    Unlike the fixed production meshes above, serving meshes come from the
    ``ServingConfig`` / ``--mesh`` flag and must work on whatever devices
    exist — 8 forced host-platform CPU devices in CI, a TPU slice in
    production. Raises with the CPU fake-device recipe when the platform
    has too few devices.
    """
    import numpy as np

    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(
            f"mesh {tuple(shape)} needs {n} devices, found {len(devs)}; "
            "on CPU, launch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n}")
    return jax.sharding.Mesh(
        np.asarray(devs[:n]).reshape(tuple(shape)), tuple(axes))


def parse_mesh_spec(spec: str):
    """Parse a ``--mesh`` CLI value: "4x2" -> ((4, 2), (data, model));
    "2x2x2" -> ((2, 2, 2), (pod, data, model)); "4" -> ((4, 1),
    (data, model)) — pure data parallelism keeps a singleton model axis,
    since the sharding rules address ``model`` by name; "1x1"/"" -> None
    (single-device serving, no mesh)."""
    if not spec:
        return None
    shape = tuple(int(x) for x in spec.lower().split("x"))
    if math.prod(shape) == 1:
        return None
    if len(shape) == 1:
        shape = (shape[0], 1)
    axes = {2: ("data", "model"), 3: ("pod", "data", "model")}.get(len(shape))
    if axes is None:
        raise ValueError(f"--mesh {spec!r}: expected 1-3 'x'-separated dims")
    return shape, axes


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
