import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any jax import (jax locks the
# device count at first init). Everything else follows.

import argparse        # noqa: E402
import dataclasses     # noqa: E402
import json            # noqa: E402
import re              # noqa: E402
import sys             # noqa: E402
import time            # noqa: E402
from typing import Any, Dict, Optional, Tuple  # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp
from repro import runtime_flags as _rtf


def _scan(*args, **kw):
    kw.update(_rtf.scan_kwargs())
    return jax.lax.scan(*args, **kw)
  # noqa: E402
import numpy as np     # noqa: E402

from repro.configs import (ALL_ARCHS, ASSIGNED_ARCHS, AquaConfig,  # noqa: E402
                           SHAPES_BY_NAME, get_config)
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig  # noqa: E402
from repro.distributed import sharding as sh  # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,  # noqa: E402
                               make_production_mesh)
from repro.models import build_model  # noqa: E402
from repro.optim import adamw  # noqa: E402

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------


def num_attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        pat = cfg.rglru.block_pattern
        return sum(1 for i in range(cfg.num_layers)
                   if pat[i % len(pat)] == "attention")
    if cfg.family == "ssm":
        return 0
    return cfg.num_layers


def proj_spec(cfg: ModelConfig) -> Optional[SDS]:
    if cfg.aqua is None or not cfg.aqua.enabled or cfg.attention is None:
        return None
    la = num_attn_layers(cfg)
    if la == 0:
        return None
    d = cfg.attention.head_dim
    return SDS((la, cfg.attention.num_kv_heads, d, d), jnp.float32)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (weak-type-correct, shardable, no device allocation)."""
    b, s = shape.global_batch, shape.seq_len
    model = build_model(cfg)
    specs: Dict[str, Any] = {}
    if shape.mode in ("train", "prefill"):
        batch = {"tokens": SDS((b, s), jnp.int32)}
        if shape.mode == "train":
            batch["labels"] = SDS((b, s), jnp.int32)
        if cfg.frontend.kind == "vision_patches":
            batch["patches"] = SDS(
                (b, cfg.frontend.num_embeds, cfg.frontend.embed_dim),
                jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = SDS((b, cfg.frontend.num_embeds, cfg.d_model),
                                  jnp.float32)
        specs["batch"] = batch
    else:  # decode
        specs["tokens"] = SDS((b,), jnp.int32)
        specs["state"] = jax.eval_shape(
            lambda: model.init_decode_state(b, s))
    specs["params"] = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    ps = proj_spec(cfg)
    if ps is not None:
        specs["proj"] = ps
    return specs


# ---------------------------------------------------------------------------
# cell construction: (fn, ordered arg specs, in_shardings)
# ---------------------------------------------------------------------------


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               microbatches: int = 2):
    model = build_model(cfg)
    specs = input_specs(cfg, shape)
    params_sh = jax.tree_util.tree_map_with_path(
        lambda p, a: sh.NamedSharding(mesh, sh.param_pspec(p, a.shape, mesh)),
        specs["params"])
    proj = specs.get("proj")
    proj_sh = None
    if proj is not None:
        proj_sh = sh.NamedSharding(
            mesh, sh.sanitize(sh.P(None, "model", None, None),
                              proj.shape, mesh))

    if shape.mode == "train":
        tcfg = TrainConfig(microbatches=microbatches)

        def train_step(params, opt, batch):
            mb = tcfg.microbatches
            split = jax.tree.map(
                lambda a: a.reshape(mb, a.shape[0] // mb, *a.shape[1:]),
                batch)

            def acc_fn(carry, micro):
                loss_c, g_c = carry
                (l, _), g = jax.value_and_grad(
                    lambda p: model.loss(p, micro), has_aux=True)(params)
                return (loss_c + l / mb,
                        jax.tree.map(lambda a, b: a + b / mb, g_c, g)), None
            zero_g = jax.tree.map(
                lambda a: jnp.zeros(a.shape, jnp.float32), params)
            (loss, grads), _ = _scan(acc_fn, (0.0, zero_g), split)
            new_params, new_opt = adamw.update(params, grads, opt, 1e-4, tcfg)
            return new_params, new_opt, loss

        opt_spec = jax.eval_shape(adamw.init, specs["params"])
        # ZeRO-1: Adam moments sharded over data axes on top of TP.
        opt_sh = jax.tree_util.tree_map_with_path(
            lambda p, a: sh.NamedSharding(
                mesh, sh.zero1_pspec(p, a.shape, mesh)), opt_spec)
        batch_sh = jax.tree.map(
            lambda a: sh.NamedSharding(mesh, sh.batch_pspec(mesh, a.shape)),
            specs["batch"])
        args = (specs["params"], opt_spec, specs["batch"])
        in_sh = (params_sh, opt_sh, batch_sh)
        out_sh = (params_sh, opt_sh, sh.NamedSharding(mesh, sh.P()))
        return train_step, args, in_sh, out_sh

    if shape.mode == "prefill":
        def prefill(params, batch, proj_arr=None):
            return model.prefill(params, batch, shape.seq_len,
                                 aqua_proj=proj_arr)
        batch_sh = jax.tree.map(
            lambda a: sh.NamedSharding(mesh, sh.batch_pspec(mesh, a.shape)),
            specs["batch"])
        args = [specs["params"], specs["batch"]]
        in_sh = [params_sh, batch_sh]
        if proj is not None:
            args.append(proj)
            in_sh.append(proj_sh)
        return prefill, tuple(args), tuple(in_sh), None

    # decode
    kvh = cfg.attention.num_kv_heads if cfg.attention is not None else 0
    state_sh = sh.make_state_shardings(specs["state"], mesh, kv_heads=kvh,
                                       batch=shape.global_batch)

    def decode(params, state, tokens, proj_arr=None):
        return model.decode_step(params, state, tokens, aqua_proj=proj_arr)

    tok_sh = sh.NamedSharding(
        mesh, sh.batch_pspec(mesh, (shape.global_batch,)))
    args = [specs["params"], specs["state"], specs["tokens"]]
    in_sh = [params_sh, state_sh, tok_sh]
    if proj is not None:
        args.append(proj)
        in_sh.append(proj_sh)
    return decode, tuple(args), tuple(in_sh), None


# ---------------------------------------------------------------------------
# HLO collective-byte accounting
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"\b(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|pred)"
    r"\[([0-9,]*)\][^=]*\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
                "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
                "pred": 1}


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-buffer bytes of every collective op in optimized HLO."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        size = 1
        if dims:
            for d in dims.split(","):
                size *= int(d)
        nbytes = size * _DTYPE_BYTES[dt]
        out[op] = out.get(op, 0) + nbytes
    return out


# ---------------------------------------------------------------------------
# run one cell
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             aqua: Optional[str] = "auto", verbose: bool = True,
             seq_parallel: bool = True, donate: bool = True,
             unroll: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]

    skip_reason = None
    if (shape_name == "long_500k" and cfg.skip_long_context
            and aqua not in ("h2o", "memory")):
        # AQUA-H2O budgets make dense 500k decode cache-feasible — run
        # those cells explicitly with --aqua h2o (beyond-paper extras).
        skip_reason = "pure full attention: quadratic prefill / unbounded " \
                      "cache at 500k (DESIGN.md §4)"
    if skip_reason:
        return {"arch": arch, "shape": shape_name, "skipped": skip_reason}

    # AQUA policy: serve cells of attention archs use the paper operating
    # point (k_ratio=0.75) unless told otherwise; train cells use standard
    # attention (AQUA is an inference technique).
    use_aqua = (aqua in ("on", "h2o", "memory") or
                (aqua == "auto" and shape.is_serve
                 and cfg.attention is not None))
    use_aqua = use_aqua and cfg.attention is not None
    if use_aqua:
        if aqua == "h2o":
            # heavy-hitter budget = 6.25% of context (32k slots at 500k)
            cfg = cfg.with_aqua(AquaConfig(k_ratio=0.75, h2o_ratio=0.0625,
                                           block_dims=8))
        elif aqua == "memory":
            cfg = cfg.with_aqua(AquaConfig(k_ratio=0.75, s_ratio=0.25,
                                           block_dims=8))
        else:
            cfg = cfg.with_aqua(AquaConfig(k_ratio=0.75, block_dims=8))

    # honest loop accounting for the roofline (see runtime_flags docstring)
    _rtf.UNROLL_SCANS = unroll
    blk_env = os.environ.get("REPRO_ANALYSIS_BLOCKS")
    if blk_env:
        _rtf.ATTN_BLOCK_OVERRIDE = tuple(int(x) for x in blk_env.split(","))
    else:
        _rtf.ATTN_BLOCK_OVERRIDE = (4096, 8192) if unroll else None

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))

    def measure(c: ModelConfig, microbatches: int = 2) -> Dict[str, Any]:
        if seq_parallel and shape.mode in ("train", "prefill"):
            sh.set_activation_sharding(sh.make_seq_parallel_sharding(
                mesh, shape.global_batch, shape.seq_len))
        else:
            sh.set_activation_sharding(None)
        if c.family == "hybrid" and shape.mode in ("train", "prefill"):
            w = c.rglru.lru_width or c.d_model
            sh.set_lru_gate_sharding(sh.make_width_sharding(
                mesh, shape.global_batch, w))
        else:
            sh.set_lru_gate_sharding(None)
        fn, args, in_sh, out_sh = build_cell(c, shape, mesh, microbatches)
        t0 = time.time()
        jit_kw: Dict[str, Any] = {}
        if donate and shape.mode == "train":
            jit_kw["donate_argnums"] = (0, 1)   # params, opt state
        elif donate and shape.mode == "decode":
            jit_kw["donate_argnums"] = (1,)     # decode state
        try:
            with mesh:
                jitted = jax.jit(fn, in_shardings=in_sh,
                                 out_shardings=out_sh, **jit_kw)
                lowered = jitted.lower(*args)
                t_l = time.time() - t0
                compiled = lowered.compile()
                t_c = time.time() - t0 - t_l
        finally:
            sh.set_activation_sharding(None)
            sh.set_lru_gate_sharding(None)
        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_d = {
                "bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
                "argument_bytes": getattr(mem, "argument_size_in_bytes",
                                          None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            }
        except Exception as e:  # CPU backend may not implement it
            mem_d = {"error": str(e)}
        coll = collective_bytes(compiled.as_text())
        return {"flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll": coll, "mem": mem_d,
                "lower_s": round(t_l, 1), "compile_s": round(t_c, 1)}

    method = "full"
    if unroll and shape.mode in ("train", "prefill") and cfg.num_layers > 6:
        # Exact layer extrapolation: layers are homogeneous, so
        # X(L) = X(n1) + (L - n1)/(n2 - n1) * (X(n2) - X(n1)) holds for
        # FLOPs / bytes / collective bytes. Compiling two shallow unrolled
        # variants is minutes instead of hours at depth 24-48.
        if cfg.family == "hybrid":
            unit = len(cfg.rglru.block_pattern)
            n1, n2 = unit, 2 * unit
        else:
            n1, n2 = 1, 2
        mb = 1  # microbatching doesn't change per-step totals
        m1 = measure(dataclasses.replace(cfg, num_layers=n1), mb)
        m2 = measure(dataclasses.replace(cfg, num_layers=n2), mb)
        scale = (cfg.num_layers - n1) / (n2 - n1)

        def extrap(a, b):
            return a + scale * (b - a)
        coll_keys = set(m1["coll"]) | set(m2["coll"])
        mres = {
            "flops": extrap(m1["flops"], m2["flops"]),
            "bytes": extrap(m1["bytes"], m2["bytes"]),
            "coll": {k: extrap(m1["coll"].get(k, 0), m2["coll"].get(k, 0))
                     for k in coll_keys},
            # memory feasibility comes from the rolled sweep, not this run
            "mem": {"note": "see rolled (non-unroll) sweep for peak memory"},
            "lower_s": m1["lower_s"] + m2["lower_s"],
            "compile_s": m1["compile_s"] + m2["compile_s"],
        }
        method = f"layer-extrapolated({n1},{n2})"
    else:
        mres = measure(cfg, 1 if unroll else 2)

    mem_d = mres["mem"]
    coll = mres["coll"]
    flops = mres["flops"]
    bytes_acc = mres["bytes"]
    t_lower, t_compile = mres["lower_s"], mres["compile_s"]
    coll_total = float(sum(coll.values()))
    # roofline terms: XLA's cost_analysis on the SPMD-partitioned module is
    # PER-PARTITION (verified against a hand-sharded matmul), i.e. already
    # per-chip work — divide only by per-chip capability.
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_acc / HBM_BW
    t_coll = coll_total / ICI_BW

    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(v) for v in mesh.shape.values()),
        "mode": shape.mode, "aqua": bool(use_aqua), "chips": chips,
        "hlo_flops": flops, "hlo_bytes": bytes_acc,
        "collective_bytes": coll, "collective_total": coll_total,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": max((("compute", t_compute), ("memory", t_memory),
                           ("collective", t_coll)), key=lambda kv: kv[1])[0],
        "memory_analysis": mem_d, "method": method,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    if verbose:
        print(json.dumps(res, indent=2, default=str))
    return res


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--aqua", default="auto",
                    choices=["auto", "on", "off", "h2o", "memory"])
    ap.add_argument("--no-seq-parallel", action="store_true",
                    help="disable activation sequence parallelism (for "
                         "before/after perf comparison)")
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll every scan so cost_analysis reports true "
                         "FLOPs/bytes (roofline runs; slower compile)")
    ap.add_argument("--sweep", action="store_true",
                    help="all (arch x shape) cells")
    ap.add_argument("--out", default=None, help="JSONL output path")
    args = ap.parse_args()

    cells = []
    if args.sweep:
        for arch in ASSIGNED_ARCHS:
            for sname in ("train_4k", "prefill_32k", "decode_32k",
                          "long_500k"):
                cells.append((arch, sname))
    else:
        assert args.arch and args.shape, "--arch/--shape or --sweep"
        cells = [(args.arch, args.shape)]

    results = []
    for arch, sname in cells:
        print(f"=== {arch} × {sname} "
              f"({'multi-pod' if args.multi_pod else 'single-pod'}) ===",
              flush=True)
        try:
            res = run_cell(arch, sname, multi_pod=args.multi_pod,
                           aqua=args.aqua,
                           seq_parallel=not args.no_seq_parallel,
                           donate=not args.no_donate, unroll=args.unroll)
        except Exception as e:
            res = {"arch": arch, "shape": sname, "error": repr(e)[:500]}
            print("FAILED:", res["error"], flush=True)
        results.append(res)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(res, default=str) + "\n")
    n_err = sum(1 for r in results if "error" in r)
    print(f"\n{len(results)} cells, {n_err} errors")
    sys.exit(1 if n_err else 0)


if __name__ == "__main__":
    main()
