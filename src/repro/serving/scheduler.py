"""Request scheduler for the continuous-batching engine.

Host-side bookkeeping only — all device work (prefill, lane surgery,
the jitted decode step) lives in ``repro.serving.engine``. The split
keeps the scheduler trivially testable and lets later PRs swap policies
(priority queues, prefill batching, preemption) without touching the
compiled step.

Request lifecycle::

    submit --> pending (arrival-ordered) --> admitted into a free *lane*
           --> [PREFILLING (chunked admission, no tokens emitted) -->]
               DECODING (one token per engine step) --> retired
               (EOS, length limit) --> lane freed for the next request

A *lane* is one batch row of the engine's shared decode state; the
number of lanes is fixed (``ServingConfig.max_lanes``) so the decode
step always runs at a static, jit-friendly shape regardless of how many
requests are in flight.

Chunked prefill (``ServingConfig.prefill_budget_tokens``) admits a long
prompt immediately into a ``LANE_PREFILLING`` lane: the engine advances
its per-lane *prefill cursor* by at most the token budget between decode
steps, and the lane transitions to ``LANE_DECODING`` (first token
sampled) only when the cursor reaches the prompt length. The scheduler
owns the cursor bookkeeping and the state machine; the engine owns the
device work and the budget spending loop.
"""

from __future__ import annotations

import bisect
import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# Lane states (``LaneScheduler.lane_state``). A free lane has state None.
LANE_PREFILLING = "prefilling"
LANE_DECODING = "decoding"


@dataclass
class Request:
    """One generation request. ``None`` sampling fields inherit the
    engine's ``ServingConfig`` defaults at submission time.

    ``arrival`` is measured in decode-step time units — the engine admits
    a request once its arrival time is <= the current step counter, which
    makes traces (e.g. Poisson arrivals) exactly reproducible.
    """

    uid: int
    tokens: np.ndarray  # (S,) int32 prompt
    max_new_tokens: Optional[int] = None  # includes the prefill-sampled token
    temperature: Optional[float] = None
    top_k: Optional[int] = None
    eos_id: Optional[int] = None
    arrival: float = 0.0
    # modality frontend inputs spliced into the prefill batch
    # (e.g. {"frames": ...} for whisper, {"patches": ...} for VLMs)
    extra_inputs: Optional[dict] = None

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])


@dataclass
class StreamEvent:
    """One streamed output token. ``index`` counts tokens within the
    request (0 = the token sampled from the prefill logits)."""

    uid: int
    token: int
    index: int
    finished: bool = False
    finish_reason: str = ""  # "eos" | "length" when finished


@dataclass
class RequestOutput:
    """Collected terminal result for one request (``engine.run``)."""

    uid: int
    prompt_len: int
    tokens: List[int] = field(default_factory=list)
    finish_reason: str = ""
    admitted_at: int = -1  # engine step counter at admission
    finished_at: int = -1


@dataclass
class ScheduleStats:
    """Aggregate trace statistics for one ``serve``/``run`` drive."""

    decode_steps: int = 0
    tokens_emitted: int = 0
    requests_finished: int = 0
    occupancy_sum: int = 0  # sum over steps of active lanes
    # chunked-prefill interleaving
    prefill_chunks: int = 0  # chunk steps executed between decode steps
    chunked_admissions: int = 0  # requests admitted in PREFILLING state
    # wall-clock gaps between consecutive emitted tokens of one request,
    # in seconds (every request's gaps pooled) — the tail of this
    # distribution is what chunked prefill exists to cut
    itl_gaps: List[float] = field(default_factory=list)

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / max(self.decode_steps, 1)

    @property
    def max_itl(self) -> float:
        return max(self.itl_gaps) if self.itl_gaps else 0.0

    def itl_percentile(self, pct: float) -> float:
        """Inter-token latency percentile in seconds (0 if no gaps)."""
        if not self.itl_gaps:
            return 0.0
        return float(np.percentile(np.asarray(self.itl_gaps), pct))

    def slo_miss_rate(self, threshold_s: float) -> float:
        """Fraction of inter-token gaps exceeding ``threshold_s``."""
        if not self.itl_gaps:
            return 0.0
        misses = sum(1 for g in self.itl_gaps if g > threshold_s)
        return misses / len(self.itl_gaps)


class LaneScheduler:
    """Admit/retire requests into a fixed set of decode lanes.

    Pending requests are kept arrival-ordered (FIFO among simultaneous
    arrivals by submission order); lanes are recycled LIFO so repeated
    light traffic stays in a warm lane prefix.

    ``lane_order`` overrides the default 0..L-1 assignment preference —
    the mesh-native engine passes an order interleaved across the data
    shards of its lane sharding, so light traffic spreads over the
    data-parallel groups instead of concentrating prefill grafts and
    active-lane occupancy on shard 0's lane block. Host-side only: the
    device step is oblivious to which lanes are preferred.
    """

    def __init__(self, max_lanes: int, lane_order: Optional[Sequence[int]] = None):
        assert max_lanes >= 1
        self.max_lanes = max_lanes
        self._pending: List[Request] = []
        self._keys: List[tuple] = []  # (arrival, seq) sort keys
        self._seq = 0
        self._lane_req: List[Optional[Request]] = [None] * max_lanes
        self._lane_state: List[Optional[str]] = [None] * max_lanes
        # chunked-prefill cursors: prompt tokens already written / total,
        # keyed by lane; ``_prefill_order`` keeps admission (FIFO) order
        # so the engine spends its per-step budget oldest-first
        self._prefill_cursor: Dict[int, int] = {}
        self._prefill_target: Dict[int, int] = {}
        self._prefill_order: List[int] = []
        order = list(range(max_lanes)) if lane_order is None else list(lane_order)
        assert sorted(order) == list(
            range(max_lanes)
        ), f"lane_order must permute 0..{max_lanes - 1}: {lane_order}"
        # stack: pop() assigns, so the preferred-first order goes reversed
        self._free: List[int] = order[::-1]

    # -- submission ----------------------------------------------------
    def submit(self, req: Request) -> None:
        key = (float(req.arrival), self._seq)
        i = bisect.bisect(self._keys, key)
        self._keys.insert(i, key)
        self._pending.insert(i, req)
        self._seq += 1

    # -- queries -------------------------------------------------------
    @property
    def has_pending(self) -> bool:
        return bool(self._pending)

    @property
    def has_work(self) -> bool:
        return bool(self._pending) or self.num_active > 0

    @property
    def num_active(self) -> int:
        return self.max_lanes - len(self._free)

    @property
    def num_decoding(self) -> int:
        return sum(1 for s in self._lane_state if s == LANE_DECODING)

    @property
    def num_prefilling(self) -> int:
        return len(self._prefill_order)

    @property
    def next_arrival(self) -> Optional[float]:
        return self._keys[0][0] if self._keys else None

    def request_in(self, lane: int) -> Request:
        req = self._lane_req[lane]
        assert req is not None, f"lane {lane} is free"
        return req

    def active_lanes(self) -> List[int]:
        return [i for i, r in enumerate(self._lane_req) if r is not None]

    def lane_state(self, lane: int) -> Optional[str]:
        return self._lane_state[lane]

    def decoding_lanes(self) -> List[int]:
        return [
            i for i, s in enumerate(self._lane_state) if s == LANE_DECODING
        ]

    def prefilling_lanes(self) -> List[int]:
        """Lanes with an in-flight chunked prefill, in admission order."""
        return list(self._prefill_order)

    # -- admission / retirement ---------------------------------------
    def pop_admissible(self, now: float, skip: int = 0) -> Optional[Request]:
        """Pop the (``skip``+1)-th pending request that has arrived, if a
        lane is free. ``skip`` > 0 is the head-of-line lookahead: when the
        queue head cannot be admitted (page pool exhausted), the engine
        retries with increasing ``skip`` so later small requests are not
        blocked by a large head (first-fit within a bounded window)."""
        if not self._free or len(self._pending) <= skip:
            return None
        if self._keys[skip][0] > now:
            return None
        self._last_key = self._keys.pop(skip)
        return self._pending.pop(skip)

    def unpop(self, req: Request) -> None:
        """Return the most recently popped request to its exact previous
        queue position (admission resource check failed — e.g. the page
        pool can't fit it yet). Keys are unique, so bisect restores the
        original order among equal arrivals."""
        key = getattr(self, "_last_key", (float(req.arrival), -1))
        i = bisect.bisect_left(self._keys, key)
        self._keys.insert(i, key)
        self._pending.insert(i, req)

    def assign(self, req: Request, prefilling: bool = False) -> int:
        lane = self._free.pop()
        self._lane_req[lane] = req
        self._lane_state[lane] = LANE_PREFILLING if prefilling else LANE_DECODING
        if prefilling:
            self._prefill_cursor[lane] = 0
            self._prefill_target[lane] = req.prompt_len
            self._prefill_order.append(lane)
        return lane

    # -- chunked-prefill state machine --------------------------------
    def begin_prefill(self, lane: int, cursor: int, target: int) -> None:
        """Set the cursor window for a PREFILLING lane: ``cursor`` tokens
        already in the cache (a shared prefix), ``target`` total prompt
        tokens to reach."""
        assert self._lane_state[lane] == LANE_PREFILLING, lane
        assert 0 <= cursor < target, (cursor, target)
        self._prefill_cursor[lane] = cursor
        self._prefill_target[lane] = target

    def prefill_cursor(self, lane: int) -> int:
        return self._prefill_cursor[lane]

    def prefill_remaining(self, lane: int) -> int:
        return self._prefill_target[lane] - self._prefill_cursor[lane]

    def advance_prefill(self, lane: int, num_tokens: int) -> None:
        """Record ``num_tokens`` prompt tokens written by one chunk."""
        assert self._lane_state[lane] == LANE_PREFILLING, lane
        assert num_tokens >= 1, num_tokens
        cur = self._prefill_cursor[lane] + num_tokens
        assert cur <= self._prefill_target[lane], (cur, lane)
        self._prefill_cursor[lane] = cur

    def mark_decoding(self, lane: int) -> None:
        """PREFILLING -> DECODING transition (final chunk done, first
        token sampled). The cursor must have reached the prompt length."""
        assert self._lane_state[lane] == LANE_PREFILLING, lane
        assert self._prefill_cursor[lane] == self._prefill_target[lane], lane
        self._lane_state[lane] = LANE_DECODING
        self._prefill_cursor.pop(lane)
        self._prefill_target.pop(lane)
        self._prefill_order.remove(lane)

    def retire(self, lane: int) -> Request:
        req = self._lane_req[lane]
        assert req is not None, f"retiring free lane {lane}"
        assert (
            self._lane_state[lane] == LANE_DECODING
        ), f"retiring lane {lane} mid-prefill"
        self._lane_req[lane] = None
        self._lane_state[lane] = None
        self._free.append(lane)
        return req


class PagePool:
    """Host-side free-list allocator for the block-paged KV cache.

    Owns the workload-to-memory scheduling decisions the device never
    sees: which physical pages back each lane's page-table row, page
    refcounts (shared prefix pages are mapped read-only into several
    lanes), and the prefix index that detects page-aligned common prompt
    prefixes. The device side (repro.core.kvcache.PagedAttnCache) only
    ever receives finished page-table rows, so every jitted step stays
    static-shaped.

    Sharing contract: only *full* pages of a prompt are shareable, so the
    divergence point is always page-aligned and shared pages are never
    written by decode (private tail/decode pages start at the divergence
    page). ``make_private`` is the copy-on-write escape hatch for any
    future policy that would write inside a shared region.

    Invariants (property-tested in tests/test_kvcache_properties.py):
      * a physical page is mapped by at most one lane unless it is a
        registered shared-prefix page,
      * refcount == number of lanes mapping the page,
      * free pages are never referenced by any lane,
      * the free list and the mapped set partition the pool.
    """

    def __init__(self, num_pages: int, page_size: int, *, prefix_sharing: bool = True):
        assert num_pages >= 1 and page_size >= 1
        self.num_pages = num_pages
        self.page_size = page_size
        self.prefix_sharing = prefix_sharing
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self.refcount = np.zeros((num_pages,), np.int64)
        self._lane_pages: Dict[int, List[int]] = {}
        # chain-hash of the full token prefix ending at each shared page
        self._prefix_index: Dict[bytes, int] = {}
        self._page_key: Dict[int, bytes] = {}
        # stats
        self.peak_in_use = 0
        self.prefix_hits = 0
        self.tokens_saved = 0
        self.util_sum = 0.0
        self.util_samples = 0

    # -- queries -------------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def utilization(self) -> float:
        return self.pages_in_use / self.num_pages

    @property
    def mean_utilization(self) -> float:
        return self.util_sum / max(self.util_samples, 1)

    def sample_utilization(self) -> None:
        """Record one utilization sample (the engine calls this per
        decode step; the bench gate judges the mean)."""
        self.util_sum += self.utilization
        self.util_samples += 1

    def lane_pages(self, lane: int) -> List[int]:
        return list(self._lane_pages.get(lane, []))

    def can_reserve(self, num_new: int) -> bool:
        return num_new <= len(self._free)

    # -- prefix sharing ------------------------------------------------
    @staticmethod
    def _chain_digests(tokens, num_pages: int, page_size: int) -> List[bytes]:
        """Rolling chain digests, one per full page:
        ``digest_i = sha1(digest_{i-1} || page_i_tokens)``. Cumulative —
        two prompts share page ``i`` only when *all* earlier tokens match
        too — and computed in one O(prompt_len) pass (re-hashing the full
        prefix per page would be quadratic on the admission path)."""
        toks = np.asarray(tokens, np.int32)
        out: List[bytes] = []
        d = b"aqua-page-chain"
        for i in range(num_pages):
            page = np.ascontiguousarray(toks[i * page_size : (i + 1) * page_size])
            d = hashlib.sha1(d + page.tobytes()).digest()
            out.append(d)
        return out

    def lookup_prefix(self, tokens) -> List[int]:
        """Longest run of already-pooled full pages matching the prompt's
        page-aligned prefix. Returns their physical page ids in logical
        order (possibly empty)."""
        if not self.prefix_sharing:
            return []
        toks = np.asarray(tokens, np.int32)
        shared: List[int] = []
        for key in self._chain_digests(
            toks, len(toks) // self.page_size, self.page_size
        ):
            pid = self._prefix_index.get(key)
            if pid is None:
                break
            shared.append(pid)
        return shared

    def register_prefix(self, tokens, pages: Sequence[int], prompt_len: int) -> None:
        """Index the full pages covered by ``prompt_len`` of a freshly
        prefilled prompt for future sharing. First writer wins: an already
        indexed chain keeps its existing physical page."""
        if not self.prefix_sharing:
            return
        toks = np.asarray(tokens, np.int32)
        digests = self._chain_digests(
            toks, prompt_len // self.page_size, self.page_size
        )
        for i, key in enumerate(digests):
            if key in self._prefix_index:
                continue
            pid = pages[i]
            self._prefix_index[key] = pid
            self._page_key[pid] = key

    # -- reserve / release --------------------------------------------
    def reserve(
        self, lane: int, shared_pages: Sequence[int], num_new: int
    ) -> Optional[List[int]]:
        """Map ``shared_pages`` (increfed) plus ``num_new`` fresh pages
        into ``lane``. Returns the lane's full page list in logical order,
        or None (nothing changed) when the free list can't cover it."""
        assert lane not in self._lane_pages, f"lane {lane} already mapped"
        if num_new > len(self._free):
            return None
        fresh = [self._free.pop() for _ in range(num_new)]
        pages = list(shared_pages) + fresh
        for p in pages:
            self.refcount[p] += 1
        self._lane_pages[lane] = pages
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return list(pages)  # snapshot: make_private may remap the lane

    def release(self, lane: int) -> None:
        """Unmap a retired lane: decref its pages; pages reaching
        refcount 0 return to the free list and leave the prefix index
        (freed pages are never referenced)."""
        for p in self._lane_pages.pop(lane, []):
            self.refcount[p] -= 1
            assert self.refcount[p] >= 0, f"page {p} refcount underflow"
            if self.refcount[p] == 0:
                key = self._page_key.pop(p, None)
                if key is not None:
                    self._prefix_index.pop(key, None)
                self._free.append(p)

    def make_private(self, lane: int, logical_page: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write: give ``lane`` a private copy of its
        ``logical_page`` if that page is shared (refcount > 1). Returns
        ``(old_phys, new_phys)`` for the caller to copy device-side, or
        None when the page was already private (no copy needed). The
        fresh page is *not* prefix-indexed (its content will diverge)."""
        pages = self._lane_pages[lane]
        old = pages[logical_page]
        if self.refcount[old] <= 1:
            return None
        if not self._free:
            raise RuntimeError("page pool exhausted during copy-on-write")
        new = self._free.pop()
        self.refcount[old] -= 1
        self.refcount[new] += 1
        pages[logical_page] = new
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return old, new


def poisson_trace(
    num_requests: int,
    *,
    mean_interarrival: float,
    prompt_lens: tuple,
    max_new_tokens: int,
    vocab_size: int,
    seed: int = 0,
    temperature: float = 0.0,
) -> List[Request]:
    """Synthetic mixed-traffic trace: Poisson arrivals (exponential
    inter-arrival times in decode-step units), prompt lengths cycled from
    ``prompt_lens``, random token prompts. Used by ``launch/serve.py``
    and the ``serving_throughput`` benchmark."""
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for i in range(num_requests):
        t += float(rng.exponential(mean_interarrival))
        s = int(prompt_lens[i % len(prompt_lens)])
        toks = rng.integers(0, vocab_size, size=(s,), dtype=np.int32)
        reqs.append(
            Request(
                uid=i,
                tokens=toks,
                max_new_tokens=max_new_tokens,
                temperature=temperature,
                arrival=t,
            )
        )
    return reqs
