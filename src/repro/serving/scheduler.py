"""Request scheduler for the continuous-batching engine.

Host-side bookkeeping only — all device work (prefill, lane surgery,
the jitted decode step) lives in ``repro.serving.engine``. The split
keeps the scheduler trivially testable and lets later PRs swap policies
(priority queues, prefill batching, preemption) without touching the
compiled step.

Request lifecycle::

    submit --> pending (arrival-ordered) --> admitted into a free *lane*
           --> decoding (one token per engine step) --> retired
               (EOS, length limit) --> lane freed for the next request

A *lane* is one batch row of the engine's shared decode state; the
number of lanes is fixed (``ServingConfig.max_lanes``) so the decode
step always runs at a static, jit-friendly shape regardless of how many
requests are in flight.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np


@dataclass
class Request:
    """One generation request. ``None`` sampling fields inherit the
    engine's ``ServingConfig`` defaults at submission time.

    ``arrival`` is measured in decode-step time units — the engine admits
    a request once its arrival time is <= the current step counter, which
    makes traces (e.g. Poisson arrivals) exactly reproducible.
    """

    uid: int
    tokens: np.ndarray                      # (S,) int32 prompt
    max_new_tokens: Optional[int] = None    # includes the prefill-sampled token
    temperature: Optional[float] = None
    top_k: Optional[int] = None
    eos_id: Optional[int] = None
    arrival: float = 0.0
    # modality frontend inputs spliced into the prefill batch
    # (e.g. {"frames": ...} for whisper, {"patches": ...} for VLMs)
    extra_inputs: Optional[dict] = None

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])


@dataclass
class StreamEvent:
    """One streamed output token. ``index`` counts tokens within the
    request (0 = the token sampled from the prefill logits)."""

    uid: int
    token: int
    index: int
    finished: bool = False
    finish_reason: str = ""                 # "eos" | "length" when finished


@dataclass
class RequestOutput:
    """Collected terminal result for one request (``engine.run``)."""

    uid: int
    prompt_len: int
    tokens: List[int] = field(default_factory=list)
    finish_reason: str = ""
    admitted_at: int = -1                   # engine step counter at admission
    finished_at: int = -1


@dataclass
class ScheduleStats:
    """Aggregate trace statistics for one ``serve``/``run`` drive."""

    decode_steps: int = 0
    tokens_emitted: int = 0
    requests_finished: int = 0
    occupancy_sum: int = 0                  # sum over steps of active lanes

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / max(self.decode_steps, 1)


class LaneScheduler:
    """Admit/retire requests into a fixed set of decode lanes.

    Pending requests are kept arrival-ordered (FIFO among simultaneous
    arrivals by submission order); lanes are recycled LIFO so repeated
    light traffic stays in a warm lane prefix.

    ``lane_order`` overrides the default 0..L-1 assignment preference —
    the mesh-native engine passes an order interleaved across the data
    shards of its lane sharding, so light traffic spreads over the
    data-parallel groups instead of concentrating prefill grafts and
    active-lane occupancy on shard 0's lane block. Host-side only: the
    device step is oblivious to which lanes are preferred.
    """

    def __init__(self, max_lanes: int,
                 lane_order: Optional[Sequence[int]] = None):
        assert max_lanes >= 1
        self.max_lanes = max_lanes
        self._pending: List[Request] = []
        self._keys: List[tuple] = []        # (arrival, seq) sort keys
        self._seq = 0
        self._lane_req: List[Optional[Request]] = [None] * max_lanes
        order = (list(range(max_lanes)) if lane_order is None
                 else list(lane_order))
        assert sorted(order) == list(range(max_lanes)), \
            f"lane_order must permute 0..{max_lanes - 1}: {lane_order}"
        # stack: pop() assigns, so the preferred-first order goes reversed
        self._free: List[int] = order[::-1]

    # -- submission ----------------------------------------------------
    def submit(self, req: Request) -> None:
        key = (float(req.arrival), self._seq)
        i = bisect.bisect(self._keys, key)
        self._keys.insert(i, key)
        self._pending.insert(i, req)
        self._seq += 1

    # -- queries -------------------------------------------------------
    @property
    def has_pending(self) -> bool:
        return bool(self._pending)

    @property
    def has_work(self) -> bool:
        return bool(self._pending) or self.num_active > 0

    @property
    def num_active(self) -> int:
        return self.max_lanes - len(self._free)

    @property
    def next_arrival(self) -> Optional[float]:
        return self._keys[0][0] if self._keys else None

    def request_in(self, lane: int) -> Request:
        req = self._lane_req[lane]
        assert req is not None, f"lane {lane} is free"
        return req

    def active_lanes(self) -> List[int]:
        return [i for i, r in enumerate(self._lane_req) if r is not None]

    # -- admission / retirement ---------------------------------------
    def pop_admissible(self, now: float) -> Optional[Request]:
        """Next pending request that has arrived, if a lane is free."""
        if not self._free or not self._pending:
            return None
        if self._keys[0][0] > now:
            return None
        self._keys.pop(0)
        return self._pending.pop(0)

    def assign(self, req: Request) -> int:
        lane = self._free.pop()
        self._lane_req[lane] = req
        return lane

    def retire(self, lane: int) -> Request:
        req = self._lane_req[lane]
        assert req is not None, f"retiring free lane {lane}"
        self._lane_req[lane] = None
        self._free.append(lane)
        return req


def poisson_trace(num_requests: int, *, mean_interarrival: float,
                  prompt_lens: tuple, max_new_tokens: int,
                  vocab_size: int, seed: int = 0,
                  temperature: float = 0.0) -> List[Request]:
    """Synthetic mixed-traffic trace: Poisson arrivals (exponential
    inter-arrival times in decode-step units), prompt lengths cycled from
    ``prompt_lens``, random token prompts. Used by ``launch/serve.py``
    and the ``serving_throughput`` benchmark."""
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for i in range(num_requests):
        t += float(rng.exponential(mean_interarrival))
        s = int(prompt_lens[i % len(prompt_lens)])
        toks = rng.integers(0, vocab_size, size=(s,), dtype=np.int32)
        reqs.append(Request(uid=i, tokens=toks,
                            max_new_tokens=max_new_tokens,
                            temperature=temperature, arrival=t))
    return reqs
