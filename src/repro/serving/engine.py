"""Batched serving engine: prefill + decode with AQUA / H2O cache policies.

A deliberately framework-shaped engine: jit-compiled prefill and decode
step functions (optionally pjit over a mesh), greedy/temperature sampling,
continuous token accounting, and per-request length tracking. The paper's
deployment story — calibrate once, serve with a chosen (k_ratio, s_ratio,
h2o_ratio) operating point — is a constructor argument.

Attention backend: both prefill and decode flow through the backend
registry in ``repro.core.attention`` (selected by
``cfg.attention.backend``, overridable per-engine via the ``backend``
constructor argument). On TPU the AQUA block-sparse chunked-prefill and
decode kernels stream only the selected key dim-blocks; off-TPU the
engine automatically serves from the masked-dense jnp reference. Prompt
batches may carry a ``"lengths"`` (B,) entry for ragged prefill: attention
masks each row's padding and decode resumes from the row's true prefix
length. Supported for dense-transformer families (dense/vlm/moe) with the
contiguous full-cache policy only — other combinations raise rather than
silently attending padding.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.calibration import AquaProjections
from repro.models import build_model


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, steps)
    logits_last: np.ndarray


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params,
                 projections: Optional[AquaProjections] = None,
                 max_seq: int = 4096, rng_seed: int = 0,
                 backend: Optional[str] = None):
        if backend is not None and cfg.attention is not None:
            from repro.core.attention import resolve_backend
            # fail fast on unknown names; accepts the "auto" selector
            resolve_backend(backend, aqua=cfg.aqua)
            cfg = dataclasses.replace(
                cfg, attention=dataclasses.replace(cfg.attention,
                                                   backend=backend))
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.proj = None
        if cfg.aqua is not None and cfg.aqua.enabled:
            assert projections is not None, \
                "AQUA enabled: calibrated projections required"
            self.proj = projections.p
        self.max_seq = max_seq
        self._rng = jax.random.PRNGKey(rng_seed)

        self._prefill = jax.jit(
            lambda p, batch, proj: self.model.prefill(p, batch, max_seq,
                                                      aqua_proj=proj))
        self._step = jax.jit(
            lambda p, state, toks, proj: self.model.decode_step(
                p, state, toks, aqua_proj=proj))

    # ------------------------------------------------------------------
    def _sample(self, logits: jax.Array, temperature: float) -> jax.Array:
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._rng, k = jax.random.split(self._rng)
        return jax.random.categorical(k, logits / temperature).astype(
            jnp.int32)

    def generate(self, batch: Dict[str, jax.Array], steps: int,
                 temperature: float = 0.0) -> GenerationResult:
        """batch: prompt inputs ({"tokens": (B, S_prompt), ...})."""
        if "lengths" in batch and self.cfg.family not in ("dense", "vlm",
                                                          "moe"):
            raise ValueError(
                "ragged `lengths` prefill is only supported by the "
                "dense-transformer families (dense/vlm/moe); "
                f"{self.cfg.family!r} prefill is rectangular")
        logits, state = self._prefill(self.params, batch, self.proj)
        out: List[np.ndarray] = []
        tok = self._sample(logits, temperature)
        out.append(np.asarray(tok))
        for _ in range(steps - 1):
            logits, state = self._step(self.params, state, tok, self.proj)
            tok = self._sample(logits, temperature)
            out.append(np.asarray(tok))
        return GenerationResult(tokens=np.stack(out, axis=1),
                                logits_last=np.asarray(logits))

    # ------------------------------------------------------------------
    def score(self, batch: Dict[str, jax.Array]) -> jax.Array:
        """Teacher-forced mean NLL of ``labels`` under the engine's AQUA
        operating point (used by the perplexity benchmarks)."""
        from repro.models.layers import cross_entropy
        logits = self.model.forward(self.params, batch, aqua_proj=self.proj)
        if isinstance(logits, tuple):
            logits = logits[0]
        return cross_entropy(logits, batch["labels"])

    def cache_bytes(self, batch_size: int) -> int:
        """Actual KV-cache footprint at this operating point (AQUA-Memory
        savings show up here)."""
        state = self.model.init_decode_state(batch_size, self.max_seq)
        return sum(a.size * a.dtype.itemsize
                   for a in jax.tree.leaves(state.layers))
