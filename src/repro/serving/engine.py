"""Serving engines: rectangular batch (``ServeEngine``) and continuous
batching (``ContinuousBatchingEngine``).

``ServeEngine`` keeps the original calibrate-once/serve API: one
rectangular prompt batch prefills together and decodes in lockstep for a
fixed number of steps. Sampling (greedy/temperature) and the RNG fold
now live *inside* the jitted decode step — the host loop never splits
keys or touches logits, so each step is a single device dispatch.

``ContinuousBatchingEngine`` is the production-shaped stack: requests
are admitted into fixed decode *lanes* (batch rows of one shared decode
state), each lane prefills independently (ragged, bucketed prompt
shapes) and its cache — including H2O ``acc_score`` and AQUA dim-sliced
key lanes — is grafted into the occupied batch via the model's lane
surgery API (``LM.prefill_into`` / ``insert_lane``). The decode step is
fully jitted at the static ``(max_lanes,)`` shape and folds in
per-request sampling (greedy / temperature / top-k, RNG derived by
``fold_in`` on the request uid and token counter so results are
independent of lane placement and co-tenants) plus EOS/length stop
detection; inactive lanes ride along under a ``write_mask`` that freezes
their cache. The host loop only drains finished lanes and streams
per-request tokens.

Attention backend: both engines flow through the backend registry in
``repro.core.attention`` (selected by ``cfg.attention.backend``,
overridable per-engine via the ``backend`` constructor argument).

Mesh-native serving: pass ``mesh=`` (or set ``ServingConfig.mesh_shape``)
and the continuous-batching engine runs the whole serve loop under an
explicit data×model mesh — params and the KV cache (AQUA dim-sliced key
lanes, H2O ``acc_score``) shard over ``model`` per
``distributed.sharding``'s rules, decode lanes shard over the data axes,
the attention cores run under ``shard_map`` — including the AQUA
block-sparse Pallas prefill/decode kernels, which serve shard_mapped
with per-shard block-index tables whenever the axis extents divide the
mesh (``distributed.sharding.kernel_shardable``) — and the lane-surgery
admission path preserves shardings end to end (every jitted entry point
is pinned with ``out_shardings``). Single-device behavior is untouched
when no mesh is configured.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from dataclasses import dataclass
from typing import Dict, Iterable, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ModelConfig, ServingConfig,
                                resolve_cache_specs, resolve_sparsity_spec)
from repro.core import kvcache as kvc
from repro.core.calibration import AquaProjections
from repro.core.dispatch import DispatchPlan, resolve_dispatch_plan
from repro.core.h2o import h2o_budget
from repro.models import build_model
from repro.models.base import DecodeState, PagingSpec
from repro.serving.scheduler import (LaneScheduler, PagePool, Request,
                                     RequestOutput, ScheduleStats,
                                     StreamEvent)

NEG_INF = -1e30


def decode_state_bytes(model, batch_size: int, max_seq: int) -> int:
    """KV-cache footprint of a decode state (shape-only: ``jax.eval_shape``
    traces ``init_decode_state`` abstractly, no device memory is touched).
    The single source of truth for cache-byte accounting — both engines'
    ``cache_bytes`` and the benches report this number. Pool-based layouts
    (paged caches) are counted once, not per lane, so AQUA-Memory *and*
    paged-pool savings both show up here."""
    state = jax.eval_shape(
        lambda: model.init_decode_state(batch_size, max_seq))
    return kvc.tree_bytes(state.layers)


# ---------------------------------------------------------------------------
# Shared sampling (jit-side)
# ---------------------------------------------------------------------------


def sample_tokens(logits: jax.Array, keys: jax.Array,
                  temperature: jax.Array, top_k: jax.Array,
                  use_top_k: bool = True) -> jax.Array:
    """Per-row sampling. logits (N, V); keys (N, ...) PRNG keys;
    temperature (N,) f32; top_k (N,) int32 (0 disables the filter; ties
    at the k-th logit are all kept). temperature <= 0 is greedy.

    ``use_top_k`` is a *static* gate: when the caller knows no row uses
    top-k it compiles the step without the full-vocab sort that the
    dynamic per-row threshold otherwise needs."""
    v = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32)
    if use_top_k:
        sorted_desc = jnp.sort(lg, axis=-1)[:, ::-1]
        idx = jnp.clip(top_k - 1, 0, v - 1)
        thr = jnp.take_along_axis(sorted_desc, idx[:, None], axis=-1)
        lg = jnp.where((top_k[:, None] <= 0) | (lg >= thr), lg, NEG_INF)
    scaled = lg / jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temperature > 0.0, sampled, greedy)


def _request_keys(rng: jax.Array, uid: jax.Array,
                  token_index: jax.Array) -> jax.Array:
    """(N,) per-request keys: fold the request uid then the token counter
    into the serve-level base key. Placement/co-tenant independent."""
    return jax.vmap(lambda u, i: jax.random.fold_in(
        jax.random.fold_in(rng, u), i))(uid, token_index)


# ---------------------------------------------------------------------------
# Rectangular-batch engine (kept for scoring, tests, and simple drives)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, steps)
    logits_last: np.ndarray


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params,
                 projections: Optional[AquaProjections] = None,
                 max_seq: int = 4096, rng_seed: int = 0,
                 backend: Optional[str] = None):
        if backend is not None and cfg.attention is not None:
            from repro.core.attention import resolve_backend
            # fail fast on unknown names; accepts the "auto" selector
            resolve_backend(backend, aqua=cfg.aqua)
            cfg = dataclasses.replace(
                cfg, attention=dataclasses.replace(cfg.attention,
                                                   backend=backend))
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.proj = None
        if cfg.aqua is not None and cfg.aqua.enabled:
            assert projections is not None, \
                "AQUA enabled: calibrated projections required"
            self.proj = projections.p
        self.max_seq = max_seq
        self._base_rng = jax.random.PRNGKey(rng_seed)
        self._calls = 0

        self._prefill = jax.jit(
            lambda p, batch, proj: self.model.prefill(p, batch, max_seq,
                                                      aqua_proj=proj))

        def step(p, state, tok, proj, rng, i, temp):
            logits, state = self.model.decode_step(p, state, tok,
                                                   aqua_proj=proj)
            return logits, state, _sample_batch(logits, rng, i, temp)
        self._step = jax.jit(step)
        self._sample0 = jax.jit(_sample_batch)

    def generate(self, batch: Dict[str, jax.Array], steps: int,
                 temperature: float = 0.0) -> GenerationResult:
        """batch: prompt inputs ({"tokens": (B, S_prompt), ...}).

        Sampling runs inside the jitted step: the per-token key is
        ``fold_in(call_key, token_index)`` — no host-side key splitting,
        no host sync beyond draining each step's sampled tokens.
        """
        if "lengths" in batch and self.cfg.family not in ("dense", "vlm",
                                                          "moe"):
            raise ValueError(
                "ragged `lengths` prefill is only supported by the "
                "dense-transformer families (dense/vlm/moe); "
                f"{self.cfg.family!r} prefill is rectangular")
        rng = jax.random.fold_in(self._base_rng, self._calls)
        self._calls += 1
        temp = jnp.float32(temperature)
        logits, state = self._prefill(self.params, batch, self.proj)
        tok = self._sample0(logits, rng, 0, temp)
        out: List[np.ndarray] = [np.asarray(tok)]
        for i in range(1, steps):
            logits, state, tok = self._step(self.params, state, tok,
                                            self.proj, rng, i, temp)
            out.append(np.asarray(tok))
        return GenerationResult(tokens=np.stack(out, axis=1),
                                logits_last=np.asarray(logits))

    # ------------------------------------------------------------------
    def score(self, batch: Dict[str, jax.Array]) -> jax.Array:
        """Teacher-forced mean NLL of ``labels`` under the engine's AQUA
        operating point (used by the perplexity benchmarks)."""
        from repro.models.layers import cross_entropy
        logits = self.model.forward(self.params, batch, aqua_proj=self.proj)
        if isinstance(logits, tuple):
            logits = logits[0]
        return cross_entropy(logits, batch["labels"])

    def cache_bytes(self, batch_size: int) -> int:
        """Actual KV-cache footprint at this operating point (AQUA-Memory
        savings show up here). See :func:`decode_state_bytes`."""
        return decode_state_bytes(self.model, batch_size, self.max_seq)


def _sample_batch(logits: jax.Array, rng: jax.Array, i,
                  temp: jax.Array) -> jax.Array:
    """Rectangular-engine sampling: per-row keys derived from the step
    key (``fold_in`` on the token counter then the row), shared
    implementation with the lane engine (no top-k on this path)."""
    key = jax.random.fold_in(rng, i)
    b = logits.shape[0]
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        key, jnp.arange(b, dtype=jnp.int32))
    return sample_tokens(logits, keys, jnp.full((b,), temp, jnp.float32),
                         jnp.zeros((b,), jnp.int32), use_top_k=False)


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclass
class LaneState:
    """Per-lane device bookkeeping folded into the jitted step."""

    last_token: jax.Array   # (L,) int32 — token fed to the next decode step
    active: jax.Array       # (L,) bool
    generated: jax.Array    # (L,) int32 — tokens emitted (incl. prefill's)
    max_new: jax.Array      # (L,) int32
    temperature: jax.Array  # (L,) f32
    top_k: jax.Array        # (L,) int32 — 0 disables
    eos_id: jax.Array       # (L,) int32 — -1 disables
    uid: jax.Array          # (L,) int32 — request uid (RNG fold key)


def _init_lane_state(num_lanes: int) -> LaneState:
    z = jnp.zeros((num_lanes,), jnp.int32)
    return LaneState(last_token=z, active=jnp.zeros((num_lanes,), bool),
                     generated=z, max_new=z,
                     temperature=jnp.zeros((num_lanes,), jnp.float32),
                     top_k=z, eos_id=z - 1, uid=z - 1)


class ContinuousBatchingEngine:
    """Continuous-batching serve stack (see the module docstring).

    Typical drive::

        eng = ContinuousBatchingEngine(cfg, params, proj,
                                       serving=ServingConfig(max_lanes=4))
        for ev in eng.serve(requests):        # StreamEvent per token
            print(ev.uid, ev.token, ev.finished)
        print(eng.stats.mean_occupancy)

    or collect terminal outputs with ``run(requests)``.

    Compilation: the decode step compiles once (static lane shape); the
    admission path compiles once per prompt *bucket* (prompts are padded
    to ``ServingConfig.prompt_bucket`` multiples and prefilled ragged via
    ``lengths`` wherever the cache policy permits — sliding-window and
    H2O policies prefill at exact prompt length instead, which costs one
    compile per distinct length).
    """

    def __init__(self, cfg: ModelConfig, params,
                 projections: Optional[AquaProjections] = None,
                 serving: ServingConfig = ServingConfig(),
                 rng_seed: int = 0, backend: Optional[str] = None,
                 mesh=None):
        if backend is not None and cfg.attention is not None:
            from repro.core.attention import resolve_backend
            resolve_backend(backend, aqua=cfg.aqua)
            cfg = dataclasses.replace(
                cfg, attention=dataclasses.replace(cfg.attention,
                                                   backend=backend))
        serving.validate()
        self.cfg = cfg
        self.scfg = serving
        # the one resolution point of the cache/quant config surface:
        # flat legacy fields warn here (once per engine), everywhere else
        # resolves silently against the same specs
        self.cache_spec, self.quant_spec = resolve_cache_specs(serving,
                                                               warn=True)
        self.sparsity_spec = resolve_sparsity_spec(serving)
        self.model = build_model(cfg)
        self.params = params
        self.proj = None
        if cfg.aqua is not None and cfg.aqua.enabled:
            assert projections is not None, \
                "AQUA enabled: calibrated projections required"
            self.proj = projections.p
        self._base_rng = jax.random.PRNGKey(rng_seed)
        self._serves = 0
        self.stats = ScheduleStats()

        # ragged bucketed prefill needs the contiguous full-cache policy
        # (window rings and H2O eviction place slots rectangularly)
        self._supports_ragged = (
            cfg.family in ("dense", "vlm", "moe")
            and (cfg.attention is None or cfg.attention.window is None)
            and h2o_budget(cfg.aqua, serving.max_seq) is None)

        # block-paged KV cache: a global page pool + per-lane page tables
        # replaces the contiguous per-lane slot stripes; the host-side
        # PagePool allocator (created per drive in serve()) hands finished
        # page-table rows to the jitted admission steps
        cache_spec, quant_spec = self.cache_spec, self.quant_spec
        self._paged = cache_spec.paged
        self.page_pool: Optional[PagePool] = None
        if self._paged:
            if cfg.attention is None or not self.model.supports_paging:
                raise ValueError(
                    f"family {cfg.family!r} does not support the paged "
                    "KV cache")
            from repro.core.kvcache import cache_slots
            slots = cache_slots(serving.max_seq, cfg.attention.window,
                                h2o_budget(cfg.aqua, serving.max_seq))
            if slots % cache_spec.page_size != 0:
                raise ValueError(
                    f"cache slots ({slots}: window/H2O budget) must be a "
                    f"multiple of page_size={cache_spec.page_size} so the "
                    "ring/eviction slot arithmetic tiles into whole pages")
            self._pages_per_lane = slots // cache_spec.page_size
            self._num_slots = slots
            num_pages = cache_spec.num_pages
            if num_pages is None:       # lane-stripe parity by default
                num_pages = serving.max_lanes * self._pages_per_lane
            # hot residents: a fraction of the pool carries the
            # full-precision write-through overlay (mixed precision)
            hot_pages = 0
            if quant_spec.quantized and quant_spec.hot_resident_fraction:
                hot_pages = max(
                    1, int(round(quant_spec.hot_resident_fraction
                                 * num_pages)))
            self.model.enable_paging(PagingSpec(
                cache_spec.page_size, num_pages,
                kv_dtype=quant_spec.kv_dtype,
                scale_granularity=quant_spec.scale_granularity,
                hot_pages=hot_pages))
            self._num_pages = num_pages
            # prefix sharing: identical page-aligned prompt prefixes map
            # the same physical pages. Needs position-pure token K/V
            # (causal, no modality frontend splice) and the full-cache
            # policy (shared pages are read-only; H2O statistics and ring
            # overwrites would write them)
            self._prefix_ok = (cache_spec.prefix_sharing
                               and self._supports_ragged
                               and cfg.frontend.kind == "none")
        else:
            self._prefix_ok = False

        # mesh-native serving: an explicit mesh (or ServingConfig.mesh_shape)
        # shards params + decode caches over `model` and decode lanes over
        # the data axes; every jitted entry point is pinned to those
        # shardings so the serve loop never reshards or bounces device state
        # through the host
        self.mesh = mesh
        if self.mesh is None and serving.mesh_shape is not None:
            from repro.launch.mesh import make_serving_mesh
            self.mesh = make_serving_mesh(serving.mesh_shape,
                                          serving.mesh_axes)
        self._lane_order = None
        # the engine's single resolved dispatch decision: backend, cache
        # layout, mesh-nativeness, and structured fallback reasons. The
        # plan is resolved from the same predicates the attention product
        # applies at trace time, so ``dispatch_plan().mesh_native`` iff
        # the mesh_fallback_events() record stays empty
        self._plan: DispatchPlan = resolve_dispatch_plan(
            attention=cfg.attention, aqua=cfg.aqua, serving=serving,
            mesh=self.mesh, prefix_sharing=self._prefix_ok,
            family=cfg.family, frontend=cfg.frontend.kind)
        self._kernel_native = self._plan.mesh_native
        # hierarchical token sparsity: resolve the per-lane participating
        # page count once (SparsitySpec is static config; the *table* is
        # per-step). None = every page participates — either the config
        # keeps everything or the plan vetoed it (REASON_TOKEN_*).
        self._kept_pages = None
        if self._paged and self._plan.token_sparsity == "hierarchical":
            kp = self.sparsity_spec.kept_pages(self._pages_per_lane)
            if kp < self._pages_per_lane:
                self._kept_pages = kp
        # per-engine mesh-fallback record: filled (and warning-deduped) by
        # the attention dispatch while this engine's steps trace, so each
        # engine owns its fallback report regardless of other engines in
        # the process (see attention.use_decode_mesh's fallback_sink)
        self._mesh_fallback: set = set()
        self._state_sh = None
        admit_sh = step_sh = None
        if self.mesh is not None:
            admit_sh, step_sh = self._install_mesh()

        # chunked-prefill interleaving: admissions longer than the token
        # budget run as page-aligned chunks between decode steps (the
        # PREFILLING lane state). The dispatch plan is the single gate —
        # it folds in every policy/family predicate (see core.dispatch)
        self._chunked = (serving.prefill_budget_tokens is not None
                         and self._plan.chunked_prefill
                         and self._supports_ragged)
        # non-final chunks must keep the cursor aligned to the prompt
        # bucket (ragged prefill batches) *and* the page size (paged tail
        # writes address whole pages); the budget is validated to be a
        # multiple of both
        self._chunk_align = self.scfg.prompt_bucket
        if self._paged:
            self._chunk_align = math.lcm(self._chunk_align,
                                         self.cache_spec.page_size)
        # block-sparse kernel prefill: fresh-prompt chunks must reproduce
        # the kernel's per-tile dim-block selection, so cursors also stay
        # q_blk-aligned and the chunk step selects per tile
        # (attention._chunk_tile_mask). Prefix-shared admissions keep the
        # per-query selection their monolithic twin (_admit_prefix) uses.
        self._tile_q_blk = None
        if (self._chunked and self._plan.backend == "aqua-block-sparse"
                and cfg.aqua is not None and cfg.aqua.enabled
                and cfg.aqua.block_dims > 1
                and (cfg.aqua.kept_dims(cfg.attention.head_dim)
                     % cfg.aqua.block_dims == 0)
                and (self.mesh is None or self._plan.mesh_native)):
            self._tile_q_blk = cfg.aqua.prefill_q_blk
            self._chunk_align = math.lcm(self._chunk_align,
                                         self._tile_q_blk)

        # `use_top_k` is static: traffic without top-k compiles the decode
        # step without the per-row dynamic-threshold full-vocab sort
        self._admit = jax.jit(self._admit_impl,
                              static_argnames=("use_top_k",),
                              out_shardings=admit_sh)
        self._admit_paged = jax.jit(self._admit_paged_impl,
                                    static_argnames=("use_top_k",),
                                    out_shardings=admit_sh)
        self._admit_prefix = jax.jit(self._admit_prefix_impl,
                                     static_argnames=("use_top_k",),
                                     out_shardings=admit_sh)
        self._step = jax.jit(self._step_impl, static_argnames=("use_top_k",),
                             out_shardings=step_sh)
        # chunk steps: non-final chunks only advance the lane's cache (no
        # token sampled, lane bookkeeping untouched); the final chunk
        # fuses the admission tail (first-token sampling) exactly like the
        # monolithic admits. The paged first chunk also installs the
        # allocator's page-table row (later chunks inherit it from state)
        self._chunk = jax.jit(self._chunk_impl,
                              static_argnames=("select_q_blk",),
                              out_shardings=self._state_sh)
        self._chunk_paged = jax.jit(self._chunk_paged_impl,
                                    static_argnames=("select_q_blk",),
                                    out_shardings=self._state_sh)
        self._chunk_final = jax.jit(self._chunk_final_impl,
                                    static_argnames=("use_top_k",
                                                     "select_q_blk"),
                                    out_shardings=admit_sh)

    def _install_mesh(self):
        """Shard params/projections, derive decode-state + lane-state
        shardings, and install them on the model (sharding-preserving lane
        surgery) and the attention path (shard_map cores / shard_mapped
        Pallas kernels). Returns (admit, step) ``out_shardings`` pinning
        the jitted entry points."""
        from repro.distributed import sharding as dsh

        mesh, s = self.mesh, self.scfg
        self.params = jax.device_put(
            self.params, dsh.make_param_shardings(self.params, mesh))
        if self.proj is not None:
            self.proj = jax.device_put(self.proj, dsh.replicated(mesh))
        att = self.cfg.attention
        kvh = att.num_kv_heads if att is not None else 0
        # kernel-native layout: when the dispatch plan picked the
        # shard_mapped Pallas kernel path (contiguous or paged), the cache
        # keeps its slot axis (and dim-blocks, and pages) whole per shard
        # — unshardable axes replicate instead of absorbing into the
        # sequence stripe. The plan is the single source; _install_mesh no
        # longer recomputes the predicate (see repro.core.dispatch).
        self._kernel_native = self._plan.mesh_native
        state_struct = jax.eval_shape(
            lambda: self.model.init_decode_state(s.max_lanes, s.max_seq))
        self._state_sh = dsh.make_state_shardings(
            state_struct, mesh, kv_heads=kvh, batch=s.max_lanes,
            kernel_native=self._kernel_native)
        self.model.set_state_shardings(self._state_sh)
        self._lane_sh = dsh.make_lane_shardings(
            jax.eval_shape(lambda: _init_lane_state(s.max_lanes)), mesh)
        self._init_state = jax.jit(
            lambda: self.model.init_decode_state(s.max_lanes, s.max_seq),
            out_shardings=self._state_sh)
        self._init_lanes = jax.jit(lambda: _init_lane_state(s.max_lanes),
                                   out_shardings=self._lane_sh)
        # admissions interleave lanes across data shards so concurrent
        # prefill grafts and active-lane occupancy spread over the
        # data-parallel groups instead of piling onto shard 0's lane block
        dsize = math.prod(mesh.shape[a] for a in ("pod", "data")
                          if a in mesh.shape)
        if dsize > 1 and s.max_lanes % dsize == 0:
            per = s.max_lanes // dsize
            self._lane_order = [g * per + i for i in range(per)
                                for g in range(dsize)]
        vec = jax.sharding.NamedSharding(mesh,
                                         dsh.lane_pspec(mesh, s.max_lanes))
        rep = dsh.replicated(mesh)
        admit_sh = (rep, rep, self._state_sh, self._lane_sh)
        step_sh = (self._state_sh, self._lane_sh, vec, vec, vec)
        return admit_sh, step_sh

    def _use_mesh(self):
        """Trace-time context: installs (or clears) the decode mesh — and
        this engine's fallback sink — plus the hierarchical token-sparsity
        participation for the attention cores while this engine's steps
        trace. Both ride ContextVars and bake into the compiled
        executables, so concurrent engines stay independent."""
        from repro.core.attention import use_decode_mesh, use_token_sparsity
        stack = contextlib.ExitStack()
        stack.enter_context(use_decode_mesh(
            self.mesh, fallback_sink=self._mesh_fallback))
        stack.enter_context(use_token_sparsity(
            self._kept_pages, self.sparsity_spec.pin_recent_pages))
        return stack

    def mesh_fallback_events(self):
        """(backend, mode, reason) mesh-kernel fallbacks traced by THIS
        engine — empty means every Pallas-backend step really served
        shard_mapped (``launch.serve --verify`` asserts this). The reason
        strings are the ``repro.core.dispatch.REASON_*`` constants, so
        trace-time events line up with ``dispatch_plan().reasons`` — a
        plan with ``mesh_native=True`` predicts this stays empty."""
        return tuple(sorted(self._mesh_fallback))

    def dispatch_plan(self) -> DispatchPlan:
        """The engine's resolved :class:`repro.core.dispatch.DispatchPlan`
        — the one public inspection point for the serving dispatch:
        backend, cache layout (contiguous/paged), ``mesh_native`` (the
        contract ``launch.serve --expect-kernel-mesh`` gates on),
        prefix-sharing, and structured fallback ``reasons``."""
        return self._plan

    @property
    def paged(self) -> bool:
        """True when this engine serves from a block-paged KV pool."""
        return self._paged

    @property
    def kept_pages(self):
        """Per-lane participating-page count when hierarchical token
        sparsity engaged (``dispatch_plan().token_sparsity ==
        'hierarchical'`` and the resolved keep is a strict subset), else
        None — every page participates."""
        return self._kept_pages

    @property
    def pool_geometry(self):
        """(num_pages, pages_per_lane, page_size) in paged mode, None
        otherwise. ``num_pages < max_lanes * pages_per_lane`` means the
        pool is smaller than the lane-stripe layout it replaces."""
        if not self._paged:
            return None
        return (self._num_pages, self._pages_per_lane, self.cache_spec.page_size)

    # -- jitted bodies -------------------------------------------------
    def _finish_admit(self, logits, lanes: LaneState, lane, rng, max_new,
                      temperature, top_k, eos_id, uid, use_top_k):
        """Shared admission tail: sample the first token from the prefill
        logits and install the lane's bookkeeping."""
        keys = _request_keys(rng, jnp.full((1,), uid, jnp.int32),
                             jnp.zeros((1,), jnp.int32))
        tok = sample_tokens(logits, keys,
                            jnp.full((1,), temperature, jnp.float32),
                            jnp.full((1,), top_k, jnp.int32),
                            use_top_k=use_top_k)
        done = ((tok == eos_id) & (eos_id >= 0)) | (max_new <= 1)
        lanes = LaneState(
            last_token=lanes.last_token.at[lane].set(tok[0]),
            active=lanes.active.at[lane].set(~done[0]),
            generated=lanes.generated.at[lane].set(1),
            max_new=lanes.max_new.at[lane].set(max_new),
            temperature=lanes.temperature.at[lane].set(temperature),
            top_k=lanes.top_k.at[lane].set(top_k),
            eos_id=lanes.eos_id.at[lane].set(eos_id),
            uid=lanes.uid.at[lane].set(uid))
        return tok, done, lanes

    def _admit_impl(self, params, batch, state, lanes: LaneState, lane,
                    proj, rng, max_new, temperature, top_k, eos_id, uid,
                    use_top_k=True):
        """Prefill one request into ``lane`` and sample its first token.
        Returns (token (1,), done (1,), state, lanes)."""
        logits, state = self.model.prefill_into(params, batch,
                                                self.scfg.max_seq, state,
                                                lane, aqua_proj=proj)
        tok, done, lanes = self._finish_admit(logits, lanes, lane, rng,
                                              max_new, temperature, top_k,
                                              eos_id, uid, use_top_k)
        return tok, done, state, lanes

    def _set_table_row(self, state, lane, table_row):
        """Install the allocator's page-table row for ``lane`` (identical
        across the stacked layer axis)."""
        layers = dataclasses.replace(
            state.layers,
            page_table=state.layers.page_table.at[:, lane].set(table_row))
        return self.model.constrain_state(
            DecodeState(layers=layers, extra=state.extra))

    def _admit_paged_impl(self, params, batch, state, lanes: LaneState,
                          lane, table_row, proj, rng, max_new, temperature,
                          top_k, eos_id, uid, use_top_k=True):
        """Paged admission: prefill to a B=1 contiguous cache, then graft
        its slots into the pages the allocator mapped for ``lane``."""
        state = self._set_table_row(state, lane, table_row)
        logits, req_state = self.model.prefill(params, batch,
                                               self.scfg.max_seq,
                                               aqua_proj=proj)
        num_slots = (batch["tokens"].shape[1] if self._supports_ragged
                     else self._num_slots)
        state = self.model.graft_paged(state, req_state, lane, num_slots)
        tok, done, lanes = self._finish_admit(logits, lanes, lane, rng,
                                              max_new, temperature, top_k,
                                              eos_id, uid, use_top_k)
        return tok, done, state, lanes

    def _admit_prefix_impl(self, params, batch, state, lanes: LaneState,
                           lane, table_row, prefix_len, proj, rng, max_new,
                           temperature, top_k, eos_id, uid, use_top_k=True):
        """Prefix-shared paged admission: the prompt's page-aligned prefix
        is already mapped into ``lane`` (read-only, refcounted); only the
        tail prefills — zero recompute on the shared prefix."""
        state = self._set_table_row(state, lane, table_row)
        logits, state = self.model.prefill_with_prefix(
            params, batch, state, lane, prefix_len, aqua_proj=proj)
        tok, done, lanes = self._finish_admit(logits, lanes, lane, rng,
                                              max_new, temperature, top_k,
                                              eos_id, uid, use_top_k)
        return tok, done, state, lanes

    def _chunk_impl(self, params, batch, state, lane, cursor, proj,
                    select_q_blk=None):
        """Advance one PREFILLING lane by a non-final prefill chunk: the
        chunk's K/V lands in logical slots starting at ``cursor``; no
        token is sampled and lane bookkeeping is untouched (the lane
        emits nothing until the final chunk)."""
        _, state = self.model.prefill_chunk(params, batch, state, lane,
                                            cursor, aqua_proj=proj,
                                            select_q_blk=select_q_blk)
        return state

    def _chunk_paged_impl(self, params, batch, state, lane, table_row,
                          cursor, proj, select_q_blk=None):
        """First paged chunk: install the allocator's page-table row,
        then advance the lane (subsequent chunks read the row from
        state)."""
        state = self._set_table_row(state, lane, table_row)
        _, state = self.model.prefill_chunk(params, batch, state, lane,
                                            cursor, aqua_proj=proj,
                                            select_q_blk=select_q_blk)
        return state

    def _chunk_final_impl(self, params, batch, state, lanes: LaneState,
                          lane, cursor, proj, rng, max_new, temperature,
                          top_k, eos_id, uid, use_top_k=True,
                          select_q_blk=None):
        """Final prefill chunk: advance the cache to the full prompt and
        sample the request's first token — the chunked twin of the
        monolithic admission tail."""
        logits, state = self.model.prefill_chunk(params, batch, state,
                                                 lane, cursor,
                                                 aqua_proj=proj,
                                                 select_q_blk=select_q_blk)
        tok, done, lanes = self._finish_admit(logits, lanes, lane, rng,
                                              max_new, temperature, top_k,
                                              eos_id, uid, use_top_k)
        return tok, done, state, lanes

    def _step_impl(self, params, state, lanes: LaneState, proj, rng,
                   use_top_k=True):
        """One decode step over all lanes: model step + per-lane sampling
        + stop detection, all compiled. Inactive lanes are frozen via
        ``write_mask`` and report ``pad_id``."""
        logits, state = self.model.decode_step(params, state,
                                               lanes.last_token,
                                               aqua_proj=proj,
                                               write_mask=lanes.active)
        keys = _request_keys(rng, lanes.uid, lanes.generated)
        tok = sample_tokens(logits, keys, lanes.temperature, lanes.top_k,
                            use_top_k=use_top_k)
        tok = jnp.where(lanes.active, tok, self.scfg.pad_id)
        emitted = lanes.active
        generated = lanes.generated + emitted.astype(jnp.int32)
        done = emitted & (((tok == lanes.eos_id) & (lanes.eos_id >= 0))
                          | (generated >= lanes.max_new))
        lanes = dataclasses.replace(
            lanes, last_token=jnp.where(emitted, tok, lanes.last_token),
            active=lanes.active & ~done, generated=generated)
        return state, lanes, tok, emitted, done

    # -- host-side drive ----------------------------------------------
    def _normalize(self, req: Request) -> Request:
        s = self.scfg
        out = dataclasses.replace(
            req,
            max_new_tokens=(s.max_new_tokens if req.max_new_tokens is None
                            else req.max_new_tokens),
            temperature=(s.temperature if req.temperature is None
                         else req.temperature),
            top_k=s.top_k if req.top_k is None else req.top_k,
            eos_id=s.eos_id if req.eos_id is None else req.eos_id)
        if out.prompt_len < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if out.prompt_len + out.max_new_tokens > s.max_seq:
            raise ValueError(
                f"request {req.uid}: prompt_len={out.prompt_len} + "
                f"max_new_tokens={out.max_new_tokens} exceeds "
                f"max_seq={s.max_seq}")
        return out

    def _prefill_batch(self, req: Request,
                       budget: Optional[int] = None) -> Dict[str, jax.Array]:
        toks = np.asarray(req.tokens, np.int32).reshape(1, -1)
        s = toks.shape[1]
        if budget is None:
            budget = self.scfg.max_seq
        if self._supports_ragged:
            bucket = self.scfg.prompt_bucket
            padded_len = max(bucket, ((s + bucket - 1) // bucket) * bucket)
            # never pad past the cache: a padded prefill longer than
            # the remaining slot budget would roll the prompt prefix out
            # of the cache (or, prefix-shared, out of the reserved pages)
            padded_len = min(padded_len, budget)
            padded = np.zeros((1, padded_len), np.int32)
            padded[0, :s] = toks[0]
            batch = {"tokens": jnp.asarray(padded),
                     "lengths": jnp.asarray([s], jnp.int32)}
        else:
            batch = {"tokens": jnp.asarray(toks)}
        if req.extra_inputs:
            batch.update(req.extra_inputs)
        return batch

    # -- paged admission planning (host side) --------------------------
    def _padded_prompt_len(self, prompt_len: int, budget: int) -> int:
        """Prefill length after bucket padding (mirrors _prefill_batch)."""
        if not self._supports_ragged:
            return prompt_len
        bucket = self.scfg.prompt_bucket
        padded = max(bucket, ((prompt_len + bucket - 1) // bucket) * bucket)
        return min(padded, budget)

    def _plan_pages(self, req: Request):
        """Decide the page reservation for an admission: how many pages
        the request needs for its whole lifetime (prefill + decode — the
        jitted steps never allocate), and which of them are shared prefix
        pages already in the pool. Returns (shared_pages, num_new) or None
        when the pool can't cover it yet (the request waits)."""
        ps = self.cache_spec.page_size
        shared: list = []
        if self._supports_ragged:
            if self._prefix_ok and not req.extra_inputs:
                # only full prompt pages are shareable, and at least one
                # tail token must remain to produce the prefill logits
                shared = self.page_pool.lookup_prefix(
                    req.tokens)[:(req.prompt_len - 1) // ps]
            prefix_len = len(shared) * ps
            tail_padded = self._padded_prompt_len(
                req.prompt_len - prefix_len, self.scfg.max_seq - prefix_len)
            total_slots = min(max(prefix_len + tail_padded,
                                  req.prompt_len + req.max_new_tokens),
                              self._num_slots)
            total_pages = -(-total_slots // ps)
        else:
            # window/H2O policies place slots across the whole logical
            # stripe (ring wrap, eviction) — reserve every page
            total_pages = self._pages_per_lane
        num_new = total_pages - len(shared)
        if not self.page_pool.can_reserve(num_new):
            return None
        return shared, num_new

    # -- chunked-prefill planning (host side) --------------------------
    def _should_chunk(self, req: Request, page_plan) -> bool:
        """Chunk this admission? Only when the engine interleaves, the
        request is token-only, and the prefill actually exceeds the
        budget — short prompts keep the monolithic admit (exact same
        path as a non-chunked engine, kernel-capable under a mesh)."""
        if not self._chunked or req.extra_inputs:
            return False
        prefix_len = 0
        if self._paged and page_plan is not None:
            prefix_len = len(page_plan[0]) * self.cache_spec.page_size
        padded = self._padded_prompt_len(req.prompt_len - prefix_len,
                                         self.scfg.max_seq - prefix_len)
        return padded > self.scfg.prefill_budget_tokens

    def _admit_chunked(self, sched: LaneScheduler, req: Request,
                       page_plan) -> tuple:
        """Admit a long prompt into a PREFILLING lane: reserve its pages
        for the whole lifetime (paged) and set the chunk cursor. No
        device work happens here — the serve loop spends the budget
        chunk by chunk. Returns (lane, job) host bookkeeping."""
        lane = sched.assign(req, prefilling=True)
        job = {"req": req, "row": None, "row_set": False,
               "register": False, "pages": None,
               "select": self._tile_q_blk}
        if self._paged:
            shared, num_new = page_plan
            pool = self.page_pool
            pages = pool.reserve(lane, shared, num_new)
            assert pages is not None      # _plan_pages checked can_reserve
            row = np.full((self._pages_per_lane,), -1, np.int32)
            row[:len(pages)] = pages
            job["row"] = jnp.asarray(row)
            job["pages"] = pages
            # prefix registration is deferred until the final chunk has
            # written the whole prompt: sharers read shared pages at
            # admission, so a half-written prompt must stay unindexed
            job["register"] = self._prefix_ok and not req.extra_inputs
            if shared:
                prefix_len = len(shared) * self.cache_spec.page_size
                pool.prefix_hits += 1
                pool.tokens_saved += prefix_len
                sched.begin_prefill(lane, prefix_len, req.prompt_len)
                # prefix-shared chunks match _admit_prefix's per-query
                # selection (the shared-prefix cursor is page-, not
                # necessarily q_blk-aligned)
                job["select"] = None
        return lane, job

    def _chunk_padded_len(self, cursor: int, count: int) -> int:
        """Tokens a chunk's prefill batch holds after bucket padding —
        the chunk's budget cost (mirrors ``_prefill_batch``'s padding,
        clamped so the padded tail never writes past the cache)."""
        bucket = self.scfg.prompt_bucket
        padded = max(bucket, ((count + bucket - 1) // bucket) * bucket)
        cap = self._num_slots if self._paged else self.scfg.max_seq
        return min(padded, cap - cursor)

    def _chunk_batch(self, req: Request, cursor: int,
                     count: int) -> Dict[str, jax.Array]:
        """Prefill batch for prompt tokens [cursor, cursor + count):
        bucket-padded with ragged ``lengths``. Non-final chunks are
        align-sized (multiples of lcm(prompt_bucket, page_size)) so their
        padding is empty and the next cursor stays page-aligned; only the
        final chunk is ragged."""
        toks = np.asarray(req.tokens, np.int32)
        padded_len = self._chunk_padded_len(cursor, count)
        padded = np.zeros((1, padded_len), np.int32)
        padded[0, :count] = toks[cursor:cursor + count]
        return {"tokens": jnp.asarray(padded),
                "lengths": jnp.asarray([count], jnp.int32)}

    def _dispatch_admit(self, req: Request, lane: int, state, lanes, rng,
                        use_top_k: bool, page_plan=None):
        """Run the right jitted admission step for ``req`` (contiguous,
        paged, or paged prefix-shared). ``page_plan`` is the
        (shared_pages, num_new) reservation decided by :meth:`_plan_pages`
        for this request (required in paged mode)."""
        common = dict(use_top_k=use_top_k)
        if not self._paged:
            with self._use_mesh():
                return self._admit(
                    self.params, self._prefill_batch(req), state, lanes,
                    jnp.int32(lane), self.proj, rng, req.max_new_tokens,
                    req.temperature, req.top_k, req.eos_id, req.uid,
                    **common)
        pool = self.page_pool
        shared, num_new = page_plan
        pages = pool.reserve(lane, shared, num_new)
        assert pages is not None      # _plan_pages checked can_reserve
        row = np.full((self._pages_per_lane,), -1, np.int32)
        row[:len(pages)] = pages
        row = jnp.asarray(row)
        ps = self.cache_spec.page_size
        if shared:
            prefix_len = len(shared) * ps
            pool.prefix_hits += 1
            pool.tokens_saved += prefix_len
            tail = dataclasses.replace(
                req, tokens=np.asarray(req.tokens)[prefix_len:])
            batch = self._prefill_batch(tail, budget=self.scfg.max_seq
                                        - prefix_len)
            with self._use_mesh():
                out = self._admit_prefix(
                    self.params, batch, state, lanes, jnp.int32(lane), row,
                    jnp.int32(prefix_len), self.proj, rng,
                    req.max_new_tokens, req.temperature, req.top_k,
                    req.eos_id, req.uid, **common)
        else:
            batch = self._prefill_batch(req)
            with self._use_mesh():
                out = self._admit_paged(
                    self.params, batch, state, lanes, jnp.int32(lane), row,
                    self.proj, rng, req.max_new_tokens, req.temperature,
                    req.top_k, req.eos_id, req.uid, **common)
        if self._prefix_ok and not req.extra_inputs:
            # both branches register: a prompt that *extends* a shared
            # prefix by further full pages indexes those pages too, so
            # later duplicates share the whole prompt, not just the part
            # the first registrant happened to cover
            pool.register_prefix(req.tokens, pages, req.prompt_len)
        return out

    def _retire(self, sched: LaneScheduler, lane: int) -> None:
        sched.retire(lane)
        if self._paged:
            self.page_pool.release(lane)

    def serve(self, requests: Iterable[Request]) -> Iterator[StreamEvent]:
        """Drive a trace of requests to completion, yielding one
        ``StreamEvent`` per generated token (in emission order). Aggregate
        trace statistics land in ``self.stats``; pool statistics (paged
        mode) in ``self.page_pool``.

        Chunked-prefill interleaving (``prefill_budget_tokens`` set and
        the dispatch plan admits it): prompts whose padded prefill
        exceeds the budget are admitted immediately into PREFILLING lanes
        and advance by at most the budget between decode steps, so a
        decoding lane never stalls behind a monolithic prefill longer
        than one chunk. Tokens are greedy-identical to monolithic
        admission — sampling keys fold the request uid and token counter,
        and chunk boundaries never change what a token computes."""
        sched = LaneScheduler(self.scfg.max_lanes,
                              lane_order=self._lane_order)
        use_top_k = False
        for r in requests:
            r = self._normalize(r)
            use_top_k |= r.top_k > 0
            sched.submit(r)
        if self._paged:
            self.page_pool = PagePool(self._num_pages, self.cache_spec.page_size,
                                      prefix_sharing=self._prefix_ok)

        rng = jax.random.fold_in(self._base_rng, self._serves)
        self._serves += 1
        if self.mesh is not None:
            state, lanes = self._init_state(), self._init_lanes()
        else:
            state = self.model.init_decode_state(self.scfg.max_lanes,
                                                 self.scfg.max_seq)
            lanes = _init_lane_state(self.scfg.max_lanes)
        # exposed for inspection/tests (terminal lane state after a drive)
        self.last_state, self.last_lanes = state, lanes
        stats = ScheduleStats()
        self.stats = stats
        emitted_count: Dict[int, int] = {}
        last_emit: Dict[int, float] = {}   # uid -> perf_counter of last yield
        jobs: Dict[int, dict] = {}         # PREFILLING lanes' bookkeeping
        budget = self.scfg.prefill_budget_tokens
        now = 0.0

        def finish_reason(tok: int, req: Request) -> str:
            return "eos" if (req.eos_id is not None and req.eos_id >= 0
                             and tok == req.eos_id) else "length"

        def record_emit(uid: int) -> None:
            t = time.perf_counter()
            if uid in last_emit:
                stats.itl_gaps.append(t - last_emit[uid])
            last_emit[uid] = t

        def first_token(req: Request, lane: int, tok, done) -> StreamEvent:
            t, d = int(tok[0]), bool(done[0])
            stats.tokens_emitted += 1
            emitted_count[req.uid] = 1
            record_emit(req.uid)
            if d:
                self._retire(sched, lane)
                stats.requests_finished += 1
                last_emit.pop(req.uid, None)
            return StreamEvent(req.uid, t, 0, d,
                               finish_reason(t, req) if d else "")

        while sched.has_work:
            # admissions: fill free lanes with every arrived request. In
            # paged mode a request only admits while the page pool covers
            # its whole lifetime (workload-to-memory scheduling, not OOM);
            # when the queue head can't fit, up to ``admission_lookahead``
            # later arrivals may admit first (bounded first-fit, no
            # head-of-line blocking) and the head keeps its exact queue
            # position for the next pass.
            while True:
                req, page_plan, skip = None, None, 0
                unbounded = sched.num_active == 0   # nothing will retire
                while True:
                    cand = sched.pop_admissible(now, skip=skip)
                    if cand is None:
                        break
                    plan = None
                    if self._paged:
                        plan = self._plan_pages(cand)
                        if plan is None:
                            sched.unpop(cand)
                            skip += 1
                            if (not unbounded
                                    and skip >= self.scfg.admission_lookahead):
                                break
                            continue
                    req, page_plan = cand, plan
                    break
                if req is None:
                    if skip > 0 and sched.num_active == 0:
                        raise RuntimeError(
                            f"page pool ({self._num_pages} pages of "
                            f"{self.cache_spec.page_size}) cannot fit any "
                            f"of the {skip} arrived request(s) even with "
                            "every lane free — raise CacheSpec.num_pages")
                    break
                if self._should_chunk(req, page_plan):
                    lane, job = self._admit_chunked(sched, req, page_plan)
                    jobs[lane] = job
                    stats.chunked_admissions += 1
                    continue
                lane = sched.assign(req)
                tok, done, state, lanes = self._dispatch_admit(
                    req, lane, state, lanes, rng, use_top_k,
                    page_plan=page_plan)
                self.last_state, self.last_lanes = state, lanes
                yield first_token(req, lane, tok, done)
            if sched.num_active == 0:
                if sched.has_pending:
                    now = max(now, sched.next_arrival)   # idle-jump
                    continue
                break

            # spend the prefill budget on PREFILLING lanes, oldest first
            # (strict FIFO: when the oldest lane's next chunk doesn't fit
            # the remaining budget, younger lanes wait too — no
            # starvation). The final chunk fuses first-token sampling and
            # flips the lane to DECODING.
            if self._chunked and sched.num_prefilling > 0:
                left = budget
                for lane in sched.prefilling_lanes():
                    job = jobs[lane]
                    req = job["req"]
                    cursor = sched.prefill_cursor(lane)
                    rem = sched.prefill_remaining(lane)
                    if rem > left:
                        # non-final chunk, align-sized so the next cursor
                        # stays bucket- and page-aligned
                        n = (left // self._chunk_align) * self._chunk_align
                        if n <= 0:
                            break
                        batch = self._chunk_batch(req, cursor, n)
                        with self._use_mesh():
                            if (job["row"] is not None
                                    and not job["row_set"]):
                                state = self._chunk_paged(
                                    self.params, batch, state,
                                    jnp.int32(lane), job["row"],
                                    jnp.int32(cursor), self.proj,
                                    select_q_blk=job["select"])
                                job["row_set"] = True
                            else:
                                state = self._chunk(
                                    self.params, batch, state,
                                    jnp.int32(lane), jnp.int32(cursor),
                                    self.proj,
                                    select_q_blk=job["select"])
                        self.last_state = state
                        sched.advance_prefill(lane, n)
                        stats.prefill_chunks += 1
                        left -= n
                        if left <= 0:
                            break
                        continue
                    padded = self._chunk_padded_len(cursor, rem)
                    if padded > left:
                        break
                    batch = self._chunk_batch(req, cursor, rem)
                    jobs.pop(lane)
                    with self._use_mesh():
                        tok, done, state, lanes = self._chunk_final(
                            self.params, batch, state, lanes,
                            jnp.int32(lane), jnp.int32(cursor), self.proj,
                            rng, req.max_new_tokens, req.temperature,
                            req.top_k, req.eos_id, req.uid,
                            use_top_k=use_top_k,
                            select_q_blk=job["select"])
                    self.last_state, self.last_lanes = state, lanes
                    sched.advance_prefill(lane, rem)
                    sched.mark_decoding(lane)
                    stats.prefill_chunks += 1
                    left -= padded
                    if job["register"]:
                        self.page_pool.register_prefix(
                            req.tokens, job["pages"], req.prompt_len)
                    yield first_token(req, lane, tok, done)
                    if left <= 0:
                        break

            # decode step over the DECODING lanes (PREFILLING lanes ride
            # along frozen under the write_mask). Skipped while only
            # prefills are in flight — time still advances, so arrivals
            # keep flowing while a long prompt chunks in.
            if sched.num_decoding > 0:
                with self._use_mesh():
                    state, lanes, tok, emitted, done = self._step(
                        self.params, state, lanes, self.proj, rng,
                        use_top_k=use_top_k)
                self.last_state, self.last_lanes = state, lanes
                tok_h = np.asarray(tok)
                em_h = np.asarray(emitted)
                done_h = np.asarray(done)
                stats.decode_steps += 1
                stats.occupancy_sum += int(em_h.sum())
                if self._paged:
                    self.page_pool.sample_utilization()
                now += 1.0
                for lane in sched.decoding_lanes():
                    if not em_h[lane]:
                        continue
                    req = sched.request_in(lane)
                    t, d = int(tok_h[lane]), bool(done_h[lane])
                    idx = emitted_count[req.uid]
                    emitted_count[req.uid] = idx + 1
                    stats.tokens_emitted += 1
                    record_emit(req.uid)
                    if d:
                        self._retire(sched, lane)
                        stats.requests_finished += 1
                        last_emit.pop(req.uid, None)
                    yield StreamEvent(req.uid, t, idx, d,
                                      finish_reason(t, req) if d else "")
            else:
                now += 1.0

    def run(self, requests: Iterable[Request]
            ) -> Dict[int, RequestOutput]:
        """Serve to completion and collect per-request terminal outputs."""
        reqs = {r.uid: r for r in requests}
        outs = {uid: RequestOutput(uid=uid, prompt_len=r.prompt_len)
                for uid, r in reqs.items()}
        for ev in self.serve(reqs.values()):
            o = outs[ev.uid]
            if ev.index == 0:
                o.admitted_at = self.stats.decode_steps
            o.tokens.append(ev.token)
            if ev.finished:
                o.finish_reason = ev.finish_reason
                o.finished_at = self.stats.decode_steps
        return outs

    def cache_bytes(self) -> int:
        """Lane-state KV footprint (shape-only, no device allocation).
        Pool-based when paging is on: the page pool is counted once, not
        ``lanes × max_seq`` — the HBM-ratio win the serving bench reports.
        See :func:`decode_state_bytes`."""
        return decode_state_bytes(self.model, self.scfg.max_lanes,
                                  self.scfg.max_seq)
