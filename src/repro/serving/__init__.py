from repro.serving.engine import ServeEngine  # noqa: F401
