from repro.serving.engine import (ContinuousBatchingEngine,  # noqa: F401
                                  GenerationResult, ServeEngine,
                                  decode_state_bytes)
from repro.serving.scheduler import (LaneScheduler, PagePool,  # noqa: F401
                                     Request, RequestOutput, ScheduleStats,
                                     StreamEvent, poisson_trace)
