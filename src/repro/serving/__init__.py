from repro.serving.engine import (ContinuousBatchingEngine,  # noqa: F401
                                  GenerationResult, ServeEngine)
from repro.serving.scheduler import (LaneScheduler, Request,  # noqa: F401
                                     RequestOutput, ScheduleStats,
                                     StreamEvent, poisson_trace)
