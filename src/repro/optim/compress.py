"""Error-feedback int8 gradient compression for the data-parallel
all-reduce (distributed-optimization trick; 4x gradient traffic reduction).

Usage inside the shard_map'd train step:

    g_q, scales = quantize(g_plus_err)
    g_sync = psum_dequant(g_q, scales, axis)    # all-reduce int8 payload
    err    = residual(g_plus_err, g_q, scales)  # carried to next step

The error-feedback residual guarantees the *accumulated* gradient signal is
unbiased over steps (Seide et al. / Karimireddy et al. style).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q int8, scale f32 scalar)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads) -> Tuple[Any, Any]:
    qs = jax.tree.map(quantize, grads)
    q = jax.tree.map(lambda t: t[0], qs,
                     is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], qs,
                     is_leaf=lambda x: isinstance(x, tuple))
    return q, s


def decompress_tree(q, s):
    return jax.tree.map(dequantize, q, s)


def residual_tree(grads, q, s):
    """Error feedback: e = g - dequant(quant(g))."""
    return jax.tree.map(
        lambda g, qq, ss: g.astype(jnp.float32) - dequantize(qq, ss),
        grads, q, s)


def ef_allreduce(grads, err, axis_name: str):
    """Error-feedback compressed all-reduce (call under shard_map).

    Returns (synced mean grads f32, new error residual)."""
    g_plus = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, err)
    q, s = compress_tree(g_plus)
    new_err = residual_tree(g_plus, q, s)
    # int8 payload all-reduce: psum the dequantized values (the int8 tensor
    # is what crosses the wire on real hardware; XLA psums the deq form —
    # byte accounting for the roofline uses the int8 size).
    deq = decompress_tree(q, s)
    synced = jax.tree.map(
        lambda g: jax.lax.pmean(g, axis_name), deq)
    return synced, new_err
