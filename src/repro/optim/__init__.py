from repro.optim import adamw, compress, schedule  # noqa: F401
