"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def cosine_with_warmup(step, cfg: TrainConfig):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.learning_rate * jnp.minimum(1.0, step / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.learning_rate * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)
