"""AdamW with global-norm clipping and mixed precision (f32 master moments
regardless of param dtype). Pure-pytree implementation (no optax)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


@jax.tree_util.register_dataclass
@dataclass
class AdamWState:
    step: jax.Array
    mu: Any
    nu: Any


def init(params) -> AdamWState:
    zeros = lambda p: jax.tree.map(
        lambda a: jnp.zeros(a.shape, jnp.float32), p)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                      nu=zeros(params))


def global_norm(grads) -> jax.Array:
    leaves = jax.tree.leaves(grads)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def update(params, grads, state: AdamWState, lr: jax.Array,
           cfg: TrainConfig) -> Tuple[Any, AdamWState]:
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + 1e-8)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
