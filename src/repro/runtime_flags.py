"""Process-wide analysis + dispatch flags.

``UNROLL_SCANS``: XLA's HloCostAnalysis counts a while-loop body ONCE, not
×trip-count (verified empirically — see EXPERIMENTS.md §Roofline/method).
Production lowering uses lax.scan for flat HLO and fast compiles; the
roofline dry-run sets this flag so every scan (layer stack, microbatch
accumulation, chunked-attention blocks) lowers fully unrolled and
cost_analysis reports true FLOPs/bytes. Compile is slower; numbers are
honest. The multi-pod feasibility sweep keeps scans rolled.

Pallas dispatch flags (set before first jit; they are read at trace time):

``INTERPRET_OVERRIDE``: force Pallas interpret mode on (True) or off
(False). ``None`` auto-resolves: compiled on TPU, interpreted elsewhere —
so the exact same kernel code path runs compiled in production and
interpreted in CI.

``PALLAS_OVERRIDE``: force the attention backend registry's view of Pallas
availability. ``None`` = auto (available iff the pallas module imports);
``False`` simulates an install without Pallas (the registry then falls
back to the masked-dense jnp reference); ``True`` additionally makes the
``auto`` backend choice prefer the Pallas kernels even off-TPU (interpret
mode — useful for kernel-path testing on CPU).
"""

UNROLL_SCANS = False

# Analysis-only override for chunked-attention block sizes (q_blk, k_blk).
# Unrolling 32k/512 × 32k/1024 = 2048 blocks per layer stalls XLA passes;
# the roofline run uses larger blocks (identical FLOPs, same HBM-byte
# totals to first order, never executed) so the unrolled graph stays
# tractable. None = production sizes.
ATTN_BLOCK_OVERRIDE = None  # Optional[Tuple[int, int]]

INTERPRET_OVERRIDE = None   # Optional[bool]
PALLAS_OVERRIDE = None      # Optional[bool]


def scan_kwargs() -> dict:
    return {"unroll": True} if UNROLL_SCANS else {}


def attn_blocks(q_blk: int, k_blk: int):
    if ATTN_BLOCK_OVERRIDE is not None:
        return ATTN_BLOCK_OVERRIDE
    return q_blk, k_blk


def on_tpu() -> bool:
    """True when the default JAX backend is a TPU."""
    import jax
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # backend init failure -> definitely not a TPU
        return False


def pallas_available() -> bool:
    """Can a Pallas kernel run at all (compiled on TPU, else interpret)?"""
    if PALLAS_OVERRIDE is not None:
        return PALLAS_OVERRIDE
    try:
        from jax.experimental import pallas  # noqa: F401
    except Exception:
        return False
    return True


def kernels_preferred() -> bool:
    """Should the ``auto`` backend choice pick Pallas kernels?

    Compiled kernels on TPU, jnp reference paths elsewhere — unless
    ``PALLAS_OVERRIDE`` forces the kernel (interpret) path for testing.
    """
    if not pallas_available():
        return False
    return on_tpu() or PALLAS_OVERRIDE is True


def resolve_interpret(interpret=None) -> bool:
    """Resolve a kernel's ``interpret`` argument: explicit value wins, then
    ``INTERPRET_OVERRIDE``, then auto-detect (compiled iff on TPU)."""
    if interpret is not None:
        return bool(interpret)
    if INTERPRET_OVERRIDE is not None:
        return bool(INTERPRET_OVERRIDE)
    return not on_tpu()
