"""Process-wide analysis flags.

``UNROLL_SCANS``: XLA's HloCostAnalysis counts a while-loop body ONCE, not
×trip-count (verified empirically — see EXPERIMENTS.md §Roofline/method).
Production lowering uses lax.scan for flat HLO and fast compiles; the
roofline dry-run sets this flag so every scan (layer stack, microbatch
accumulation, chunked-attention blocks) lowers fully unrolled and
cost_analysis reports true FLOPs/bytes. Compile is slower; numbers are
honest. The multi-pod feasibility sweep keeps scans rolled.
"""

UNROLL_SCANS = False

# Analysis-only override for chunked-attention block sizes (q_blk, k_blk).
# Unrolling 32k/512 × 32k/1024 = 2048 blocks per layer stalls XLA passes;
# the roofline run uses larger blocks (identical FLOPs, same HBM-byte
# totals to first order, never executed) so the unrolled graph stays
# tractable. None = production sizes.
ATTN_BLOCK_OVERRIDE = None  # Optional[Tuple[int, int]]


def scan_kwargs() -> dict:
    return {"unroll": True} if UNROLL_SCANS else {}


def attn_blocks(q_blk: int, k_blk: int):
    if ATTN_BLOCK_OVERRIDE is not None:
        return ATTN_BLOCK_OVERRIDE
    return q_blk, k_blk
