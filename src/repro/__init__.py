"""repro — production-grade JAX framework implementing AQUA
(Attention via QUery mAgnitudes, 2025)."""

__version__ = "1.0.0"
