"""recurrentgemma-9b — hybrid RG-LRU + local attention, 1:2. [arXiv:2402.19427]

38L d_model=4096 16H (MQA kv=1, head_dim=256) d_ff=12288 vocab=256000,
block pattern (recurrent, recurrent, attention), local window 2048.
"""
from repro.configs.base import AttentionConfig, ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    d_ff=12288,
    vocab_size=256000,
    attention=AttentionConfig(num_heads=16, num_kv_heads=1, head_dim=256,
                              kind="local", window=2048, rope_theta=10000.0),
    rglru=RGLRUConfig(lru_width=0, conv_width=4,
                      block_pattern=("recurrent", "recurrent", "attention")),
    act="gelu",
)
