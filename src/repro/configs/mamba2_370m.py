"""mamba2-370m — attention-free SSD (state-space duality). [arXiv:2405.21060]

48L d_model=1024, ssm_state=128, head_dim=64, expand=2.

AQUA is INAPPLICABLE (no query-key dot product); see DESIGN.md
§Arch-applicability. Implemented without the technique.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256),
)
