"""qwen3-0.6b — dense GQA with per-head q/k RMSNorm. [hf:Qwen/Qwen3 family]

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936, explicit head_dim=128.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    d_ff=3072,
    vocab_size=151936,
    attention=AttentionConfig(num_heads=16, num_kv_heads=8, head_dim=128,
                              qk_norm=True, rope_theta=1000000.0),
    tie_embeddings=True,
    skip_long_context=True,
)
