"""llama-3.1-8b — the paper's primary evaluation model (§8.1).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, head_dim=128.
Not part of the assigned pool; included because the paper's experiments
target it and the fidelity benchmarks mirror its GQA group structure
(group size 4, as in paper Fig. 2).
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3.1-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=128256,
    attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                              rope_theta=500000.0),
    skip_long_context=True,
)
