"""qwen1.5-4b — dense with QKV bias.

40L d_model=2560 20H (GQA kv=20 == MHA) d_ff=6912 vocab=151936
[hf:Qwen/Qwen1.5 family]
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    d_ff=6912,
    vocab_size=151936,
    attention=AttentionConfig(
        num_heads=20, num_kv_heads=20, head_dim=128,
        qkv_bias=True, rope_theta=1000000.0),
    skip_long_context=True,  # pure full attention
)
