"""h2o-danube-1.8b — dense, llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000  [arXiv:2401.16818]
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    d_ff=6912,
    vocab_size=32000,
    attention=AttentionConfig(
        num_heads=32, num_kv_heads=8, head_dim=80,
        kind="swa", window=4096, rope_theta=10000.0),
    tie_embeddings=False,
)
