"""Architecture config registry.

``get_config(name)`` returns the full production config; ``--arch <id>``
in the launchers resolves through ``ARCHS``. ``reduced(name)`` returns the
CPU-smoke-test variant of the same family.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (  # noqa: F401
    AquaConfig, AttentionConfig, FrontendConfig, ModelConfig, MoEConfig,
    RGLRUConfig, SHAPES, SHAPES_BY_NAME, ShapeConfig, SSMConfig,
    TrainConfig, reduce_config,
)

# arch id -> module name
_MODULES: Dict[str, str] = {
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "qwen1.5-4b": "qwen15_4b",
    "minitron-4b": "minitron_4b",
    "qwen3-0.6b": "qwen3_0_6b",
    "mamba2-370m": "mamba2_370m",
    "pixtral-12b": "pixtral_12b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a27b",
    "whisper-tiny": "whisper_tiny",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "llama3.1-8b": "llama31_8b",
}

#: The 10 assigned architectures (llama3.1-8b is extra: the paper's model).
ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "llama3.1-8b")
ALL_ARCHS = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg: ModelConfig = mod.CONFIG
    cfg.validate()
    return cfg


def reduced(name: str, **kw) -> ModelConfig:
    return reduce_config(get_config(name), **kw)
