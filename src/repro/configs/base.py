"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; AQUA is a
first-class, orthogonal ``AquaConfig`` attached to any attention-bearing
model. Configs are plain frozen dataclasses so they hash/compare cleanly
and can be used as jit static args.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class AquaConfig:
    """Paper hyperparameters (§8.1, §8.4) plus TPU-adaptation knobs."""

    enabled: bool = True
    # Fraction of (remaining) dims kept for the score dot-product (paper k_ratio).
    k_ratio: float = 0.75
    # AQUA-Memory static slice: fraction of trailing principal dims dropped
    # before caching (paper S_ratio). 0.0 disables AQUA-Memory.
    s_ratio: float = 0.0
    # H2O heavy-hitter cache budget as a fraction of full context
    # (paper H2O_ratio). 1.0 disables eviction.
    h2o_ratio: float = 1.0
    # Fraction of the H2O budget reserved for the most recent tokens.
    h2o_recent_frac: float = 0.5
    # TPU adaptation: magnitude selection granularity in dims. 1 = exact
    # paper semantics (per-dim); 8 = sublane-block granularity used by the
    # Pallas kernel's scalar-prefetch DMA path.
    block_dims: int = 1
    # Fold P into W_Q / W_K offline when legal (no per-step projection cost).
    fold_projection: bool = True
    # Block-sparse kernel tile sizes (repro.kernels.aqua_prefill/aqua_decode):
    # queries per prefill chunk (one dim-block selection per chunk), keys per
    # prefill tile, and keys per decode seq-block. Threaded through the
    # attention backend registry (repro.core.attention).
    prefill_q_blk: int = 128
    prefill_k_blk: int = 128
    decode_seq_blk: int = 128

    @property
    def e_ratio(self) -> float:
        """Paper's effective ratio for AQUA-Memory."""
        return (1.0 - self.s_ratio) * self.k_ratio

    def kept_dims(self, head_dim: int) -> int:
        """Dims retained after the static slice (AQUA-Memory stage 1)."""
        d = int(round((1.0 - self.s_ratio) * head_dim))
        return max(self.block_dims, min(head_dim, d))

    def topk_dims(self, head_dim: int) -> int:
        """Dims kept by dynamic magnitude selection (stage 2)."""
        d_kept = self.kept_dims(head_dim)
        k = int(round(self.k_ratio * d_kept))
        k = max(self.block_dims, min(d_kept, k))
        # round up to selection granularity
        b = self.block_dims
        return ((k + b - 1) // b) * b


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    kind: str = "full"           # full | swa (sliding-window) | local
    window: Optional[int] = None  # for swa/local
    qk_norm: bool = False         # qwen3-style per-head RMSNorm on q,k
    qkv_bias: bool = False        # qwen1.5-style bias on q,k,v projections
    rope_theta: float = 10000.0
    use_rope: bool = True         # False -> absolute learned positions (whisper)
    causal: bool = True           # False for encoder self-attention
    # Attention backend registry key (repro.core.attention): "auto" |
    # "dense-jnp" | "flash" | "aqua-masked-dense" | "aqua-block-sparse".
    # "auto" picks Pallas kernels on TPU and jnp references elsewhere;
    # explicit kernel backends fall back to the masked-dense reference when
    # Pallas is unavailable.
    backend: str = "auto"

    @property
    def group_size(self) -> int:
        assert self.num_heads % self.num_kv_heads == 0
        return self.num_heads // self.num_kv_heads


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int
    num_shared: int = 0          # qwen2-moe shared experts
    router_aux_weight: float = 0.01
    router_jitter: float = 0.0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block parameters."""

    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 64
    ngroups: int = 1


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block parameters."""

    lru_width: int = 0            # 0 -> d_model
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")


@dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontends (audio frames / vision patches).

    ``input_specs`` provides precomputed embeddings of shape
    (batch, num_embeds, embed_dim); the model projects and splices them.
    """

    kind: str = "none"            # none | audio_frames | vision_patches
    num_embeds: int = 0
    embed_dim: int = 0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    aqua: Optional[AquaConfig] = None
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # encoder-decoder (whisper): encoder depth; decoder uses num_layers.
    num_encoder_layers: int = 0
    act: str = "silu"             # silu | gelu
    max_positions: int = 32768    # learned-position table size (use_rope=False)
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True            # activation checkpointing per block
    # long-context capability flag (drives shape applicability):
    # sub-quadratic if SSM/hybrid or windowed attention.
    skip_long_context: bool = False

    @property
    def subquadratic(self) -> bool:
        if self.family in ("ssm", "hybrid"):
            return True
        if self.attention is not None and self.attention.kind in ("swa", "local"):
            return True
        return False

    def with_aqua(self, aqua: AquaConfig) -> "ModelConfig":
        return replace(self, aqua=aqua)

    def validate(self) -> None:
        assert self.family in ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")
        if self.family != "ssm":
            assert self.attention is not None
        if self.family == "moe":
            assert self.moe is not None
        if self.family == "hybrid":
            assert self.rglru is not None
        if self.family == "encdec":
            assert self.num_encoder_layers > 0


def reduce_config(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
                  vocab: int = 128, ff: int = 128) -> ModelConfig:
    """Shrink a production config to a CPU-smoke-testable size, preserving
    every structural feature (GQA ratio, qk_norm, MoE routing, SWA, ...)."""
    kw: dict = dict(num_layers=layers, d_model=d_model, vocab_size=vocab, d_ff=ff)
    if cfg.attention is not None:
        heads = max(2, min(4, cfg.attention.num_heads))
        # preserve GQA-ness: kv < heads iff original had grouping
        kv = heads if cfg.attention.num_kv_heads == cfg.attention.num_heads else max(1, heads // 2)
        kw["attention"] = replace(
            cfg.attention, num_heads=heads, num_kv_heads=kv,
            head_dim=max(8, d_model // heads),
            window=None if cfg.attention.window is None else 16)
    if cfg.moe is not None:
        kw["moe"] = replace(cfg.moe, num_experts=8,
                            top_k=min(2, cfg.moe.top_k), expert_ff=ff // 2,
                            num_shared=min(1, cfg.moe.num_shared),
                            capacity_factor=8.0)  # effectively dropless
    if cfg.ssm is not None:
        kw["ssm"] = replace(cfg.ssm, state_dim=16, head_dim=16, chunk_size=8)
    if cfg.rglru is not None:
        kw["rglru"] = replace(cfg.rglru, lru_width=0)
    if cfg.frontend.kind != "none":
        kw["frontend"] = replace(cfg.frontend, num_embeds=4, embed_dim=32)
    if cfg.num_encoder_layers:
        kw["num_encoder_layers"] = 2
    kw["remat"] = False
    kw["dtype"] = "float32"
    return replace(cfg, **kw)


@dataclass(frozen=True)
class CacheSpec:
    """KV-cache layout: the one non-deprecated way to configure the
    serving cache (resolved once at engine construction, like
    ``core.dispatch.DispatchPlan``).

    ``page_size`` tokens per page turns the per-lane contiguous slot
    stripes into a global page pool with per-lane page tables
    (``repro.core.kvcache.PagedAttnCache``); None keeps the contiguous
    layout. ``num_pages`` sizes the pool (None = lane-stripe parity:
    ``max_lanes * slots / page_size``) — set it lower to realize the
    memory win (admissions queue when the pool is full).
    ``prefix_sharing`` maps identical page-aligned prompt prefixes into
    multiple lanes (refcounted, copy-on-write; paged full-cache policy
    only). ``eviction`` names the slot-eviction policy; ``"auto"``
    derives it from the model config (H2O when ``AquaConfig.h2o_ratio``
    < 1, ring when the attention is windowed, none otherwise) — the
    explicit names exist for config introspection and forward-compat,
    the engine rejects a name that contradicts the model policy.
    """

    page_size: Optional[int] = None
    num_pages: Optional[int] = None
    prefix_sharing: bool = True
    eviction: str = "auto"        # auto | none | ring | h2o

    @property
    def paged(self) -> bool:
        return self.page_size is not None

    def validate(self) -> None:
        assert self.eviction in ("auto", "none", "ring", "h2o"), self.eviction
        if self.page_size is not None:
            assert self.page_size >= 1
            if self.num_pages is not None:
                assert self.num_pages >= 1
        elif self.num_pages is not None:
            raise ValueError("CacheSpec.num_pages needs page_size (paged "
                             "layout only)")


@dataclass(frozen=True)
class QuantSpec:
    """KV-pool quantization (paged layout only).

    ``kv_dtype``: pool storage dtype — ``"bf16"`` keeps full-precision
    pools, ``"int8"`` stores per-page symmetric-quantized K̂/V with f32
    scales living beside the page table (zero-point 0; scales ride the
    Pallas decode kernel's scalar-prefetch ``index_map`` for
    dequant-free, scale-folded score accumulation).
    ``scale_granularity``: ``"page_head"`` keeps one scale per
    (page, kv-head); ``"page"`` shares one scale across a page's heads
    (half the metadata, coarser clipping).
    ``hot_resident_fraction``: fraction of the pool kept as
    full-precision *hot residents* — pages with the highest H2O
    accumulated scores carry a write-through bf16 overlay beside their
    (always-written) int8 twin, and readers prefer the overlay. 0
    disables mixed precision (every page reads quantized).
    """

    kv_dtype: str = "bf16"              # bf16 | int8
    scale_granularity: str = "page_head"  # page_head | page
    hot_resident_fraction: float = 0.0

    @property
    def quantized(self) -> bool:
        return self.kv_dtype != "bf16"

    @property
    def mode(self) -> str:
        """Dispatch-plan label: none | int8 | int8-mixed."""
        if not self.quantized:
            return "none"
        return (f"{self.kv_dtype}-mixed" if self.hot_resident_fraction > 0
                else self.kv_dtype)

    def validate(self) -> None:
        assert self.kv_dtype in ("bf16", "int8"), self.kv_dtype
        assert self.scale_granularity in ("page_head", "page"), \
            self.scale_granularity
        assert 0.0 <= self.hot_resident_fraction <= 1.0, \
            self.hot_resident_fraction


@dataclass(frozen=True)
class SparsitySpec:
    """Two-stage hierarchical sparsity (paged layout only).

    Sibling of :class:`CacheSpec`/:class:`QuantSpec` — the third leg of
    the unified serving-config surface, resolved once at engine
    construction (:func:`resolve_sparsity_spec`).

    **Stage 1 (token sparsity, page-granular):** each decode step ranks a
    lane's mapped pages by their H2O accumulated attention mass
    (``PagedAttnCache.acc_pool`` — the statistic the pool already
    maintains, a free block-ranking signal where HyperAttention uses LSH)
    and only the top ``page_keep_ratio`` fraction *participates* in
    attention at all; the last ``pin_recent_pages`` pages of the lane
    (the tail holding the probe token and the local window) are always
    kept, so recency is exact. Pages with no accumulated mass tie at
    zero and resolve to the lowest page indices — the selection then
    degrades gracefully to attention-sink + recent-tail behavior.

    **Stage 2 (dim sparsity):** AQUA's per-query |q̂| dim-block top-k,
    unchanged, applied only within participating pages.

    The participating-page set rides the Pallas decode kernel's
    scalar-prefetch ``index_map`` exactly like page ids and quant scales,
    so non-participating pages cost zero HBM bytes — decode compute and
    bandwidth scale with ``kept_pages``, not context length.
    ``page_keep_ratio=1.0`` disables stage 1 (bit-identical to the plain
    paged kernel: the participation table is the identity map).
    """

    page_keep_ratio: float = 1.0
    # Recency pin: the trailing pages of each lane (by token count) are
    # always in the participating set, independent of their scores.
    pin_recent_pages: int = 2

    @property
    def hierarchical(self) -> bool:
        return self.page_keep_ratio < 1.0

    def kept_pages(self, pages_per_lane: int) -> int:
        """Static participating-set size for a lane of
        ``pages_per_lane`` logical pages (the kernel grid extent)."""
        k = math.ceil(self.page_keep_ratio * pages_per_lane - 1e-9)
        k = max(k, min(self.pin_recent_pages, pages_per_lane), 1)
        return min(k, pages_per_lane)

    def validate(self) -> None:
        assert 0.0 < self.page_keep_ratio <= 1.0, self.page_keep_ratio
        assert self.pin_recent_pages >= 1, self.pin_recent_pages


def resolve_sparsity_spec(serving: "ServingConfig") -> "SparsitySpec":
    """Resolve a ``ServingConfig``'s token-sparsity surface — the
    :class:`SparsitySpec` twin of :func:`resolve_cache_specs` (no legacy
    flat fields to shim; hierarchical mode cross-validates against the
    cache layout the same way quantization does)."""
    spec = serving.sparsity if serving.sparsity is not None else SparsitySpec()
    spec.validate()
    if spec.hierarchical:
        cache, _ = resolve_cache_specs(serving, warn=False)
        if not cache.paged:
            raise ValueError(
                f"SparsitySpec(page_keep_ratio={spec.page_keep_ratio}) "
                "needs the paged cache layout — stage-1 selection is "
                "page-granular; set CacheSpec.page_size")
    return spec


# ServingConfig fields shadowed by CacheSpec: (flat name, CacheSpec name,
# deprecated-iff-not-this default). One-release DeprecationWarning shims
# (the kernel_native shim pattern from PR 6, removed in PR 7).
_LEGACY_CACHE_FIELDS = (("page_size", "page_size", None),
                        ("num_pages", "num_pages", None),
                        ("prefix_sharing", "prefix_sharing", True))


def resolve_cache_specs(serving: "ServingConfig", *, warn: bool = True
                        ) -> Tuple[CacheSpec, QuantSpec]:
    """Resolve a ``ServingConfig``'s cache surface to (CacheSpec,
    QuantSpec) — the single resolution point, called once per engine
    (``warn=True``) and silently by ``validate()``/dispatch resolution
    (``warn=False``).

    The old flat fields (``page_size``/``num_pages``/``prefix_sharing``)
    are one-release deprecated shims: set them and a DeprecationWarning
    names the replacement; set them *and* ``cache=`` and resolution
    fails loudly instead of silently preferring one side.
    """
    legacy = [flat for flat, _, default in _LEGACY_CACHE_FIELDS
              if getattr(serving, flat) != default]
    if legacy:
        if serving.cache is not None:
            raise ValueError(
                f"ServingConfig sets both cache=CacheSpec(...) and the "
                f"deprecated flat field(s) {legacy} — move the flat "
                "values into the CacheSpec")
        if warn:
            warnings.warn(
                f"ServingConfig.{'/'.join(legacy)} are deprecated; pass "
                "cache=CacheSpec(page_size=..., num_pages=..., "
                "prefix_sharing=...) instead (one-release shim)",
                DeprecationWarning, stacklevel=3)
    if serving.cache is not None:
        cache = serving.cache
    else:
        cache = CacheSpec(page_size=serving.page_size,
                          num_pages=serving.num_pages,
                          prefix_sharing=serving.prefix_sharing)
    quant = serving.quant if serving.quant is not None else QuantSpec()
    cache.validate()
    quant.validate()
    if quant.quantized and not cache.paged:
        raise ValueError(
            f"QuantSpec(kv_dtype={quant.kv_dtype!r}) needs the paged "
            "cache layout — quantization state is per-page metadata; "
            "set CacheSpec.page_size")
    return cache, quant


@dataclass(frozen=True)
class ServingConfig:
    """Continuous-batching engine knobs (repro.serving).

    A *lane* is one batch row of the shared decode state. Requests are
    admitted into free lanes and retired independently, so the decode
    step always runs at the static shape ``(max_lanes,)`` — jit compiles
    exactly once regardless of traffic.
    """

    max_lanes: int = 8
    max_seq: int = 4096
    # per-request defaults (overridable per Request)
    max_new_tokens: int = 128
    temperature: float = 0.0
    top_k: int = 0               # 0 disables top-k filtering
    eos_id: int = -1             # -1 disables EOS stop detection
    pad_id: int = 0              # token reported for inactive lanes
    # Prompts are right-padded to a multiple of this bucket and prefilled
    # with ragged ``lengths`` so prefill compiles once per bucket, not once
    # per prompt length. Policies that reject ragged prefill (sliding
    # window, H2O eviction) fall back to exact-length prefill.
    prompt_bucket: int = 16
    # Device mesh for mesh-native serving: ``mesh_shape`` (e.g. (4, 2))
    # over ``mesh_axes`` (data × model). None serves single-device. Decode
    # lanes are data-parallel over the data axes; params and the KV cache
    # (including AQUA dim-sliced key lanes and H2O acc_score) shard over
    # the model axis per distributed.sharding's name+shape rules.
    mesh_shape: Optional[Tuple[int, ...]] = None
    mesh_axes: Tuple[str, ...] = ("data", "model")
    # DEPRECATED flat cache fields (one-release shims): use
    # ``cache=CacheSpec(page_size=..., num_pages=..., prefix_sharing=...)``
    # instead. Setting any of them emits a DeprecationWarning at engine
    # construction; setting them alongside ``cache=`` is an error (see
    # :func:`resolve_cache_specs`).
    page_size: Optional[int] = None
    num_pages: Optional[int] = None
    prefix_sharing: bool = True
    # Chunked-prefill/decode interleaving: cap the prefill tokens advanced
    # per decode step. Prompts longer than the budget are admitted
    # immediately into a PREFILLING lane and their KV cache is built in
    # page-aligned chunks between decode steps, so active decode lanes
    # never stall longer than one chunk. None keeps monolithic admission
    # (the whole prefill runs inside the admit). Must be a multiple of
    # ``prompt_bucket`` (and of ``page_size`` when paged) so every
    # non-final chunk lands bucket- and page-aligned.
    prefill_budget_tokens: Optional[int] = None
    # Admission head-of-line lookahead: number of queue positions tried
    # first-fit per admission pass when the head cannot reserve pages —
    # the head plus up to ``admission_lookahead - 1`` later *arrived*
    # requests. 1 = strict FIFO (head-only, the pre-lookahead behavior).
    # Skipped-over requests keep their exact queue position.
    admission_lookahead: int = 4
    # The unified cache-configuration surface (the only non-deprecated
    # one): layout/geometry in ``cache``, pool quantization in ``quant``.
    # None means defaults (contiguous layout, bf16 pools) — or, one
    # release longer, whatever the deprecated flat fields above say.
    cache: Optional[CacheSpec] = None
    quant: Optional[QuantSpec] = None
    # Two-stage hierarchical sparsity (page-granular token sparsity ×
    # AQUA dim-block sparsity). None means SparsitySpec() defaults: every
    # page participates (no token sparsity).
    sparsity: Optional[SparsitySpec] = None

    def validate(self) -> None:
        assert self.max_lanes >= 1
        assert self.max_new_tokens >= 1
        assert self.prompt_bucket >= 1
        assert self.admission_lookahead >= 1
        cache, _ = resolve_cache_specs(self, warn=False)
        resolve_sparsity_spec(self)
        if self.prefill_budget_tokens is not None:
            assert self.prefill_budget_tokens >= 1
            assert self.prefill_budget_tokens % self.prompt_bucket == 0, \
                (self.prefill_budget_tokens, self.prompt_bucket)
            if cache.page_size is not None:
                assert self.prefill_budget_tokens % cache.page_size == 0, \
                    (self.prefill_budget_tokens, cache.page_size)
        if cache.page_size is not None:
            assert self.max_seq % cache.page_size == 0, \
                (self.max_seq, cache.page_size)
        if self.mesh_shape is not None:
            assert len(self.mesh_shape) == len(self.mesh_axes), \
                (self.mesh_shape, self.mesh_axes)
            assert all(s >= 1 for s in self.mesh_shape), self.mesh_shape
            assert all(a in ("pod", "data", "model")
                       for a in self.mesh_axes), self.mesh_axes


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    mode: str                      # train | prefill | decode

    @property
    def is_serve(self) -> bool:
        return self.mode in ("prefill", "decode")


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatches: int = 1          # gradient accumulation
    grad_compress: bool = False    # int8 error-feedback allreduce
    seed: int = 0
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
