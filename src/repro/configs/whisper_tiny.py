"""whisper-tiny — encoder-decoder, conv audio frontend (STUB). [arXiv:2212.04356]

4L enc + 4L dec, d_model=384 6H (MHA) d_ff=1536 vocab=51865.
The conv frontend is stubbed: input_specs provides precomputed frame
embeddings (batch, frames, d_model) fed straight to the encoder.
"""
from repro.configs.base import AttentionConfig, FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,
    num_encoder_layers=4,
    d_model=384,
    d_ff=1536,
    vocab_size=51865,
    attention=AttentionConfig(num_heads=6, num_kv_heads=6, head_dim=64,
                              use_rope=False),
    frontend=FrontendConfig(kind="audio_frames", num_embeds=1500,
                            embed_dim=384),
    act="gelu",
    skip_long_context=True,
)
