"""minitron-4b — width-pruned nemotron. [arXiv:2407.14679]

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    d_ff=9216,
    vocab_size=256000,
    attention=AttentionConfig(num_heads=24, num_kv_heads=8, head_dim=128,
                              rope_theta=10000.0),
    act="silu",
    skip_long_context=True,
)
