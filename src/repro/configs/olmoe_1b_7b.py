"""olmoe-1b-7b — MoE, 64 experts top-8, MHA. [arXiv:2409.02060]

This is also the paper's own second evaluation model (OLMoE-1B-7B-Instruct),
so it doubles as a direct reproduction target.

16L d_model=2048 16H (kv=16, MHA) expert_ff=1024 vocab=50304.
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    d_ff=1024,
    vocab_size=50304,
    attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=128,
                              qk_norm=True, rope_theta=10000.0),
    moe=MoEConfig(num_experts=64, top_k=8, expert_ff=1024),
    skip_long_context=True,
)
