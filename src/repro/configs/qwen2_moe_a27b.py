"""qwen2-moe-a2.7b — MoE with shared experts. [hf:Qwen/Qwen1.5-MoE-A2.7B]

24L d_model=2048 16H (kv=16, MHA) expert_ff=1408, 60 routed top-4 +
4-shared-expert-equivalent shared path, vocab=151936.
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    d_ff=1408,
    vocab_size=151936,
    attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=128,
                              qkv_bias=True, rope_theta=1000000.0),
    moe=MoEConfig(num_experts=60, top_k=4, expert_ff=1408, num_shared=4),
    skip_long_context=True,
)
