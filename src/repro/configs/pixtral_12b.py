"""pixtral-12b — VLM: mistral-nemo backbone, pixtral-ViT frontend (STUB).

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128.
The vision frontend supplies precomputed patch embeddings via input_specs.
[hf:mistralai/Pixtral-12B-2409]
"""
from repro.configs.base import AttentionConfig, FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    d_ff=14336,
    vocab_size=131072,
    attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                              rope_theta=1000000.0),
    frontend=FrontendConfig(kind="vision_patches", num_embeds=256,
                            embed_dim=1024),
    skip_long_context=True,
)
