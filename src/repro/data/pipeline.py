"""Deterministic, stateless-indexed synthetic data pipeline.

Every batch is a pure function of (seed, step) — no iterator state, no
coordination. This is the straggler/elasticity story: a restarted or
re-sharded worker recomputes exactly its slice of any step's batch from
the index alone, and data-parallel groups slice the same global batch by
shard id. Checkpoint resume needs only the step counter.

Two generators:
  * ``lcg_batch`` — a learnable synthetic language (affine next-token rule
    per sequence) used by convergence tests and the e2e example; a model
    that attends properly drives loss to ~0.
  * ``uniform_batch`` — i.i.d. tokens for throughput/benchmark runs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "lcg"          # lcg | uniform


def _keys(cfg: DataConfig, step: int) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)


def lcg_batch(cfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    """tokens[t+1] = (a * tokens[t] + c) mod V with per-sequence (a, c)."""
    key = _keys(cfg, step)
    k1, k2, k3 = jax.random.split(key, 3)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    a = jax.random.randint(k1, (b, 1), 1, min(v, 17))
    c = jax.random.randint(k2, (b, 1), 0, v)
    x0 = jax.random.randint(k3, (b, 1), 0, v)

    def step_fn(x, _):
        nxt = (a[:, 0] * x + c[:, 0]) % v
        return nxt, nxt
    _, seq = jax.lax.scan(step_fn, x0[:, 0], None, length=s)
    tokens = jnp.concatenate([x0, seq.T], axis=1)[:, :s + 1]
    return {"tokens": tokens[:, :-1].astype(jnp.int32),
            "labels": tokens[:, 1:].astype(jnp.int32)}


def copy_batch(cfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    """Copy language: a random prefix of length S/2 followed by its repeat.
    Predicting the second half requires attending ~S/2 tokens back — a
    long-range task where AQUA's approximation quality is actually load-
    bearing (unlike the Markovian LCG rule). ``loss_mask`` restricts the
    loss to the attention-dependent second half."""
    key = _keys(cfg, step)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    half = (s + 1) // 2 + 1
    prefix = jax.random.randint(key, (b, half), 0, v, jnp.int32)
    seq = jnp.concatenate([prefix, prefix], axis=1)[:, :s + 1]
    pos = jnp.arange(s)
    mask = (pos[None, :] >= half - 1).astype(jnp.float32)
    return {"tokens": seq[:, :-1], "labels": seq[:, 1:],
            "loss_mask": jnp.broadcast_to(mask, (b, s))}


def uniform_batch(cfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    key = _keys(cfg, step)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    tokens = jax.random.randint(key, (b, s + 1), 0, v, jnp.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def make_batch(cfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    fn = {"lcg": lcg_batch, "uniform": uniform_batch,
          "copy": copy_batch}[cfg.kind]
    return fn(cfg, step)


def add_frontend_inputs(batch: Dict[str, jax.Array], mcfg: ModelConfig,
                        step: int = 0) -> Dict[str, jax.Array]:
    """Stub modality frontends: precomputed frame/patch embeddings."""
    b = batch["tokens"].shape[0]
    fe = mcfg.frontend
    key = jax.random.PRNGKey(step + 7)
    if fe.kind == "vision_patches":
        batch["patches"] = jax.random.normal(
            key, (b, fe.num_embeds, fe.embed_dim), jnp.float32)
    elif fe.kind == "audio_frames":
        batch["frames"] = jax.random.normal(
            key, (b, fe.num_embeds, mcfg.d_model), jnp.float32)
    return batch


def calibration_batches(mcfg: ModelConfig, *, num_batches: int = 4,
                        batch: int = 2, seq: int = 128, seed: int = 1234):
    """Calibration corpus iterator for ``repro.core.calibration`` (stands in
    for BookCorpus, paper §6.1 step 1)."""
    dcfg = DataConfig(vocab_size=mcfg.vocab_size, seq_len=seq,
                      global_batch=batch, seed=seed)
    for i in range(num_batches):
        b = make_batch(dcfg, i)
        yield add_frontend_inputs({"tokens": b["tokens"]}, mcfg, i)
