"""Deterministic, stateless-indexed data pipeline.

Every batch is a pure function of (seed, step) — no iterator state, no
coordination. This is the straggler/elasticity story: a restarted or
re-sharded worker recomputes exactly its slice of any step's batch from
the index alone, and data-parallel groups slice the same global batch by
shard id. Checkpoint resume needs only the step counter.

Synthetic generators:
  * ``lcg_batch`` — a learnable synthetic language (affine next-token rule
    per sequence) used by convergence tests and the e2e example; a model
    that attends properly drives loss to ~0.
  * ``uniform_batch`` — i.i.d. tokens for throughput/benchmark runs.
  * ``copy_batch`` — prefix-repeat language whose quality depends on
    long-range attention.

Real-text source:
  * ``corpus_batch`` — windows from a tokenized file
    (``DataConfig.corpus_path``): a ``.npy``/``.npz`` array of token ids,
    or a ``.txt``/``.text`` file tokenized byte-level (UTF-8 bytes; ids
    fold into the vocab). Window starts hash from (seed, step, row), so
    the same stateless-index contract holds — this is the calibration
    corpus source for the real-weights SVD step (paper §6.1 step 1;
    ``corpora/calibration.txt`` ships a small real-text sample for
    network-free CI).
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "lcg"  # lcg | uniform | copy | corpus
    corpus_path: Optional[str] = None  # required for kind="corpus"


def _keys(cfg: DataConfig, step: int) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)


def lcg_batch(cfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    """tokens[t+1] = (a * tokens[t] + c) mod V with per-sequence (a, c)."""
    key = _keys(cfg, step)
    k1, k2, k3 = jax.random.split(key, 3)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    a = jax.random.randint(k1, (b, 1), 1, min(v, 17))
    c = jax.random.randint(k2, (b, 1), 0, v)
    x0 = jax.random.randint(k3, (b, 1), 0, v)

    def step_fn(x, _):
        nxt = (a[:, 0] * x + c[:, 0]) % v
        return nxt, nxt

    _, seq = jax.lax.scan(step_fn, x0[:, 0], None, length=s)
    tokens = jnp.concatenate([x0, seq.T], axis=1)[:, : s + 1]
    return {
        "tokens": tokens[:, :-1].astype(jnp.int32),
        "labels": tokens[:, 1:].astype(jnp.int32),
    }


def copy_batch(cfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    """Copy language: a random prefix of length S/2 followed by its repeat.
    Predicting the second half requires attending ~S/2 tokens back — a
    long-range task where AQUA's approximation quality is actually load-
    bearing (unlike the Markovian LCG rule). ``loss_mask`` restricts the
    loss to the attention-dependent second half."""
    key = _keys(cfg, step)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    half = (s + 1) // 2 + 1
    prefix = jax.random.randint(key, (b, half), 0, v, jnp.int32)
    seq = jnp.concatenate([prefix, prefix], axis=1)[:, : s + 1]
    pos = jnp.arange(s)
    mask = (pos[None, :] >= half - 1).astype(jnp.float32)
    return {
        "tokens": seq[:, :-1],
        "labels": seq[:, 1:],
        "loss_mask": jnp.broadcast_to(mask, (b, s)),
    }


def uniform_batch(cfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    key = _keys(cfg, step)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    tokens = jax.random.randint(key, (b, s + 1), 0, v, jnp.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


# ---------------------------------------------------------------------------
# Tokenized-file corpus source
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=8)
def load_token_corpus(path: str, vocab_size: int) -> np.ndarray:
    """1-D int32 token ids from a corpus file, folded into ``vocab_size``.

    ``.npy``/``.npz`` files hold pre-tokenized ids (any integer dtype; an
    ``.npz`` uses its first array). ``.txt``/``.text`` files tokenize
    byte-level: each UTF-8 byte is one token — crude, but real text with
    real statistics, which is all the calibration Gram accumulation needs
    (and exactly reproducible with zero tokenizer dependencies)."""
    ext = os.path.splitext(path)[1].lower()
    if ext in (".npy", ".npz"):
        loaded = np.load(path)
        arr = loaded[loaded.files[0]] if hasattr(loaded, "files") else loaded
        ids = np.asarray(arr).reshape(-1)
        if not np.issubdtype(ids.dtype, np.integer):
            raise ValueError(f"token corpus {path!r} must hold integer ids")
    elif ext in (".txt", ".text"):
        with open(path, "rb") as f:
            ids = np.frombuffer(f.read(), dtype=np.uint8)
    else:
        raise ValueError(
            f"unsupported corpus format {ext!r} for {path!r} "
            "(expected .npy/.npz token ids or .txt byte-level text)"
        )
    return (ids.astype(np.int64) % vocab_size).astype(np.int32)


def corpus_batch(cfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    """Deterministic windows over a tokenized corpus file.

    Window starts are a pure hash of (seed, step, row) — a Weyl-style
    multiplicative hash over the valid start range — so any worker can
    recompute any step's batch from the index alone, matching the
    synthetic generators' contract."""
    assert cfg.corpus_path is not None, 'kind="corpus" needs corpus_path'
    tokens = load_token_corpus(cfg.corpus_path, cfg.vocab_size)
    b, s = cfg.global_batch, cfg.seq_len
    n = tokens.size - (s + 1)
    if n <= 0:
        raise ValueError(
            f"corpus {cfg.corpus_path!r} has {tokens.size} tokens; "
            f"need > seq_len + 1 = {s + 2}"
        )
    row = np.arange(b, dtype=np.int64)
    mix = (cfg.seed * 1_000_003 + step * b + row) * 2_654_435_761
    starts = (mix % n).astype(np.int64)
    windows = tokens[starts[:, None] + np.arange(s + 1)[None, :]]
    return {
        "tokens": jnp.asarray(windows[:, :-1], jnp.int32),
        "labels": jnp.asarray(windows[:, 1:], jnp.int32),
    }


_GENERATORS = {
    "lcg": lcg_batch,
    "uniform": uniform_batch,
    "copy": copy_batch,
    "corpus": corpus_batch,
}


def make_batch(cfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    return _GENERATORS[cfg.kind](cfg, step)


def add_frontend_inputs(
    batch: Dict[str, jax.Array], mcfg: ModelConfig, step: int = 0
) -> Dict[str, jax.Array]:
    """Stub modality frontends: precomputed frame/patch embeddings."""
    b = batch["tokens"].shape[0]
    fe = mcfg.frontend
    key = jax.random.PRNGKey(step + 7)
    if fe.kind == "vision_patches":
        batch["patches"] = jax.random.normal(
            key, (b, fe.num_embeds, fe.embed_dim), jnp.float32
        )
    elif fe.kind == "audio_frames":
        batch["frames"] = jax.random.normal(
            key, (b, fe.num_embeds, mcfg.d_model), jnp.float32
        )
    return batch


def calibration_batches(
    mcfg: ModelConfig,
    *,
    num_batches: int = 4,
    batch: int = 2,
    seq: int = 128,
    seed: int = 1234,
    corpus_path: Optional[str] = None,
):
    """Calibration corpus iterator for ``repro.core.calibration``.

    With ``corpus_path`` the batches are real-text windows from that file
    (the paper's BookCorpus role, §6.1 step 1); otherwise the synthetic
    LCG language stands in."""
    kind = "corpus" if corpus_path is not None else "lcg"
    dcfg = DataConfig(
        vocab_size=mcfg.vocab_size,
        seq_len=seq,
        global_batch=batch,
        seed=seed,
        kind=kind,
        corpus_path=corpus_path,
    )
    for i in range(num_batches):
        b = make_batch(dcfg, i)
        yield add_frontend_inputs({"tokens": b["tokens"]}, mcfg, i)
