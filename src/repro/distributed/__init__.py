from repro.distributed import sharding  # noqa: F401
