"""Sharding rules: parameter/optimizer/cache PartitionSpecs for the
production meshes.

Design (DESIGN.md §3):
  * ``pod`` × ``data`` is the pure data-parallel domain (batch axis).
  * ``model`` carries tensor parallelism (attention KV-heads / query
    groups, FFN hidden, vocab) and expert parallelism (MoE expert axis).
  * ``long_500k`` (batch=1) shards the decode cache's *sequence* axis over
    ``data`` (context parallelism); GSPMD inserts the flash-decode-style
    combine collectives.

Rules are name+shape based and **divisibility-sanitized**: a candidate
axis that doesn't divide the dimension falls back to the next candidate
(e.g. qwen2-moe's 60 experts can't split 16 ways -> expert-ff TP instead;
MQA's single KV head -> shard query groups / head_dim instead; batch=1
-> replicate batch). This makes every (arch × shape × mesh) cell feasible
without per-arch hand-tuning.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def sanitize(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop spec entries whose mesh-axis size doesn't divide the dim, or
    that name an axis the mesh doesn't carry (data-only serving meshes
    have no ``model`` axis)."""
    out = []
    for i in range(len(shape)):
        s = spec[i] if i < len(spec) else None
        if s is not None:
            axes = (s,) if isinstance(s, str) else tuple(s)
            if any(a not in mesh.shape for a in axes):
                s = None
            elif shape[i] % _axis_size(mesh, s) != 0:
                s = None
        out.append(s)
    return P(*out)


def _spec_at(ndim: int, dim_from_end: int, axes) -> P:
    lst = [None] * ndim
    if 0 <= ndim + dim_from_end < ndim:
        lst[ndim + dim_from_end] = axes
    return P(*lst)


def _first_feasible(cands: Sequence[P], shape, mesh: Mesh) -> P:
    for c in cands:
        if len(shape) < len(c):
            continue
        if sanitize(c, shape, mesh) == P(*c, *([None] * (len(shape) - len(c)))):
            return sanitize(c, shape, mesh)
    return P(*([None] * len(shape)))


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

_REPLICATED_NAMES = {"ln", "ln1", "ln2", "ln_x", "ln_f", "enc_ln", "q_norm",
                     "k_norm", "out_norm", "lam", "dt_bias", "b"}


def param_pspec(path, shape, mesh: Mesh, model_axis: str = "model") -> P:
    name = path_str(path).split("/")[-1]
    nd = len(shape)
    m = model_axis
    if name in _REPLICATED_NAMES or nd == 0:
        return P(*([None] * nd))
    cands = {
        "wq": [_spec_at(nd, -3, m), _spec_at(nd, -2, m)],
        "wk": [_spec_at(nd, -2, m), _spec_at(nd, -1, m)],
        "wv": [_spec_at(nd, -2, m), _spec_at(nd, -1, m)],
        "wo": [_spec_at(nd, -4, m), _spec_at(nd, -3, m)],
        "bq": [_spec_at(nd, -3, m), _spec_at(nd, -2, m)],
        "bk": [_spec_at(nd, -2, m)],
        "bv": [_spec_at(nd, -2, m)],
        "w2": [_spec_at(nd, -2, m)],
        "router": [_spec_at(nd, -1, m)],
        "table": [_spec_at(nd, -2, m), _spec_at(nd, -1, m)],
        "pos": [_spec_at(nd, -1, m)],
        "wout": [_spec_at(nd, -2, m)],
        "out_proj": [_spec_at(nd, -2, m)],
        "a_log": [_spec_at(nd, -1, m)],
        "d_skip": [_spec_at(nd, -1, m)],
    }.get(name)
    if cands is None:
        if name in ("w1", "w3"):
            if nd >= 4:  # MoE experts (L, E, dm, f): EP first, then ff-TP
                cands = [_spec_at(nd, -3, m), _spec_at(nd, -1, m)]
            else:
                cands = [_spec_at(nd, -1, m)]
        else:
            # generic projections (in_proj, wx, wgate, wr, wi, conv_w,
            # conv_b, shared_gate, patch w, ...): shard the output dim.
            cands = [_spec_at(nd, -1, m)]
    return _first_feasible(cands, shape, mesh)


def zero1_pspec(path, shape, mesh: Mesh, model_axis: str = "model") -> P:
    """ZeRO-1: optimizer-state sharding. Start from the parameter's TP spec
    and additionally shard the largest still-replicated dim over the data
    axes — Adam moments drop from params-bytes to params-bytes/(data·model)
    per device. The update runs on shards; GSPMD all-gathers the new params
    (same volume as the gradient reduce-scatter it replaces)."""
    base = param_pspec(path, shape, mesh, model_axis)
    dp = data_axes(mesh)
    if not dp:
        return base
    dims = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in dims:
        if base[i] is None and shape[i] % _axis_size(mesh, dp) == 0:
            lst = list(base) + [None] * (len(shape) - len(base))
            lst[i] = dp
            return P(*lst)
    return base


def make_param_shardings(params, mesh: Mesh, model_axis: str = "model"):
    def one(path, leaf):
        return NamedSharding(mesh, param_pspec(path, leaf.shape, mesh,
                                               model_axis))
    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# activation / batch / decode-state rules
# ---------------------------------------------------------------------------


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_pspec(mesh: Mesh, shape, extra_dims: int = 1) -> P:
    """(B, ...) activations: shard batch over pod×data if divisible."""
    dp = data_axes(mesh)
    spec = P(dp, *([None] * (len(shape) - 1)))
    s = sanitize(spec, shape, mesh)
    if s[0] is None and len(dp) > 1:
        # try data-only (e.g. B=16 on a (2,16,16) mesh)
        s = sanitize(P(dp[-1], *([None] * (len(shape) - 1))), shape, mesh)
    return s


def batch_shardings(batch, mesh: Mesh):
    return jax.tree.map(
        lambda a: NamedSharding(mesh, batch_pspec(mesh, a.shape)), batch)


def decode_state_pspec(path, shape, mesh: Mesh, *,
                       kv_shardable: bool = True,
                       batch_shardable: bool = True,
                       slot_absorb: bool = True,
                       model_axis: str = "model") -> P:
    """Sharding for DecodeState leaves (stacked or per-layer caches).

    When KV heads don't divide the model axis (GQA kv=8 on a 16-way axis,
    MQA, MHA with odd head counts) the cache's *slot/sequence* axis takes
    the model axis instead (flash-decode style context parallelism); when
    the batch doesn't divide pod×data (long_500k B=1) the slot axis absorbs
    the data axes too.

    ``slot_absorb=False`` disables that absorption: the slot axis (and the
    trailing dim axis) stay whole per shard, replicating the unshardable
    axis instead. The serving engine uses this when the AQUA block-sparse
    kernels serve the state shard_mapped — the kernels stream full
    dim-major K̂ sequence stripes per (lane, head) shard, so a slot-sharded
    (or dim-block-splitting) layout would force a gather at the shard_map
    boundary every step.
    """
    name = path_str(path).split("/")[-1]
    nd = len(shape)
    dp = data_axes(mesh)
    base = {
        "k": 4, "v": 4, "positions": 2, "count": 1, "acc_score": 3,
        "conv": 3, "state": 2,
    }.get(name)
    batch_ax = dp if batch_shardable else None
    kv_ax = model_axis if kv_shardable else None
    # paged-cache leaves: the page *pool* is global across lanes (any lane
    # may map any page), so it never shards over the data axes — KV heads
    # (and the whole dim-blocks of the dim-major K̂ view riding on the
    # trailing dim) shard over `model`, page tables ride the lane/batch
    # axis, positions replicate (tiny).
    paged = {"k_pool": 4, "v_pool": 4, "acc_pool": 3, "pos_pool": 2,
             "page_table": 2, "k_scale": 2, "v_scale": 2,
             "k_hot": 4, "v_hot": 4, "hot_ids": 1}.get(name)
    if paged is not None:
        pad = [None] * (nd - paged)
        if name in ("k_pool", "v_pool", "k_hot", "v_hot"):
            # pools ((L,) P, KV, ps, D); hot overlay ((L,) H, KV, ps, D)
            spec = P(*pad, None, kv_ax, None, None)
        elif name == "acc_pool":               # ((L,) P, KV, ps)
            spec = P(*pad, None, kv_ax, None)
        elif name == "page_table":             # ((L,) B, NP)
            spec = P(*pad, batch_ax, None)
        elif name in ("k_scale", "v_scale"):   # ((L,) P, SH)
            # per-page quant scales partition with their pages' KV heads
            # over `model` (page axis stays whole, like the pool); the
            # one-scale-per-page granularity (SH=1) sanitizes to
            # replicated.
            spec = P(*pad, None, kv_ax)
        elif name == "hot_ids":                # ((L,) H) — tiny, replicated
            spec = P(*pad, None)
        else:                                  # pos_pool ((L,) P, ps)
            spec = P(*pad, None, None)
        return sanitize(spec, shape, mesh)
    slot_axes = tuple(
        ((() if batch_shardable else dp)
         + (() if kv_shardable else (model_axis,)))
        if slot_absorb else ())
    # canonicalize: bare axis name for singletons (PartitionSpec equality
    # distinguishes "model" from ("model",))
    slot_ax = (slot_axes[0] if len(slot_axes) == 1 else slot_axes) \
        if slot_axes else None
    lead = nd - base if base is not None else 0
    pad = [None] * lead

    def build(*tail):
        return P(*pad, *tail)
    if base is None:
        # extra entries (whisper cross K/V): (L, B, S_enc, KV, D)
        if nd >= 5:
            return sanitize(P(None, batch_ax, None, kv_ax, None), shape, mesh)
        return P(*([None] * nd))
    if name in ("k", "v"):
        spec = build(batch_ax, kv_ax, slot_ax, None)
    elif name == "positions":
        spec = build(batch_ax, slot_ax)
    elif name == "count":
        spec = build(batch_ax)
    elif name == "acc_score":
        spec = build(batch_ax, kv_ax, slot_ax)
    elif name == "conv":
        spec = build(batch_ax, None, model_axis)
    elif name == "state":
        if nd - lead >= 4 or nd >= 4:   # ssm ((L,) B, H, P, N)
            spec = P(*([None] * (nd - 4)), batch_ax, model_axis, None, None)
        else:                           # rglru ((L,) B, W)
            spec = P(*([None] * (nd - 2)), batch_ax, model_axis)
    else:
        spec = P(*([None] * nd))
    return sanitize(spec, shape, mesh)


def make_state_shardings(state, mesh: Mesh, *, kv_heads: int, batch: int,
                         kernel_native: bool = False):
    """``kernel_native=True``: the AQUA block-sparse Pallas kernels will
    serve this state shard_mapped, so the cache layout must keep every
    slot/sequence stripe — and every dim-block of the dim-major K̂ view —
    whole per shard (see ``decode_state_pspec``'s ``slot_absorb``)."""
    model = mesh.shape.get("model", 1)   # data-only meshes: no TP axis
    kv_ok = kv_heads > 0 and kv_heads % model == 0
    b_ok = batch % _axis_size(mesh, data_axes(mesh)) == 0

    def one(path, leaf):
        return NamedSharding(mesh, decode_state_pspec(
            path, leaf.shape, mesh, kv_shardable=kv_ok, batch_shardable=b_ok,
            slot_absorb=not kernel_native))
    return jax.tree_util.tree_map_with_path(one, state)


# The paged decode kernel tiles each page into whole 8-token sequence
# sub-blocks (TPU sublane granularity; ops.aqua_paged_decode clamps
# seq_blk to the page size, so a non-multiple page would leave a ragged
# tail block the index_map can't address).
KERNEL_PAGE_MULTIPLE = 8


def kernel_shardable(mesh: Optional[Mesh], cfg, aqua=None, *,
                     batch: Optional[int] = None,
                     page_size: Optional[int] = None) -> bool:
    """Can the Pallas attention kernels run shard_mapped under ``mesh``?

    Geometry-only predicate (policy checks — H2O, sliding window,
    ``block_dims > 1`` — stay with the dispatch sites in
    ``repro.core.attention`` and ``repro.core.dispatch``):

    * For AQUA-native kernels (``aqua`` given) the kept dims must tile
      into whole ``block_dims`` dim-blocks, so every model shard holds
      whole dim-blocks of the dim-major K̂ cache.
    * A multi-row batch must divide the data axes — lanes partition into
      whole per-data-shard groups (contiguous caches *and* paged page
      tables ride the lane axis). When it doesn't,
      :func:`decode_state_pspec` has already moved the mesh axes onto the
      cache's *slot* axis (context parallelism), and the kernels — which
      stream full sequence stripes per (lane, head) shard — would force a
      gather at the shard_map boundary; those shapes keep the jnp
      reference path. ``batch == 1`` (admission prefills) replicates the
      batch axis instead and stays kernel-runnable, as does MQA's single
      KV head (the head axis replicates).
    * Paged geometry (``page_size`` given): pages must tile into whole
      :data:`KERNEL_PAGE_MULTIPLE`-token sequence blocks. No *sharding*
      divisibility applies to the pool itself: ``model`` only ever
      shards the pool's KV-head axis (dim-blocks and pages ride whole
      per model shard), and the pool never splits over the data axes —
      any lane may map any physical page, so page-table entries are
      pool-global ids valid unchanged on every data shard (see
      :func:`decode_state_pspec`'s paged branch).
    """
    if mesh is None:
        return False
    if aqua is not None:
        if not aqua.enabled or aqua.block_dims < 1:
            return False
        if aqua.kept_dims(cfg.head_dim) % aqua.block_dims != 0:
            return False
    if batch is not None and batch > 1:
        if batch % _axis_size(mesh, data_axes(mesh)) != 0:
            return False
    if page_size is not None and page_size % KERNEL_PAGE_MULTIPLE != 0:
        return False
    return True


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Serving lane rules (continuous-batching engine).
#
# A decode *lane* is one batch row of the shared decode state; the engine's
# per-lane vectors (LaneState fields, sampled tokens, stop flags) are (L,)
# arrays whose axis is the same batch axis the decode caches carry — so both
# shard over the data axes together, keeping the jitted sample-in-step
# decode data-parallel end to end (no gather between the model step and the
# per-lane sampler).
# ---------------------------------------------------------------------------


def lane_pspec(mesh: Mesh, num_lanes: int) -> P:
    """(L,) per-lane vectors: shard over pod×data when divisible."""
    dp = data_axes(mesh)
    if not dp:
        return P(None)
    return sanitize(P(dp), (num_lanes,), mesh)


def page_rank_pspec(mesh: Mesh, batch: int) -> P:
    """(B, KP) hierarchical participating-page tables: lane-partitioned
    over pod×data exactly like ``page_table`` rows (the entries are
    logical per-lane page indices, meaningless across lanes), table
    width whole per shard."""
    dp = data_axes(mesh)
    if not dp:
        return P(None, None)
    return sanitize(P(dp, None), (batch, 1), mesh)


def make_lane_shardings(tree, mesh: Mesh):
    """NamedShardings for a pytree of (L,) / (L, ...) per-lane leaves
    (leading axis = lane). Non-lane trailing dims stay replicated."""
    def one(leaf):
        spec = lane_pspec(mesh, leaf.shape[0])
        return NamedSharding(mesh, P(spec[0], *([None] * (len(leaf.shape)
                                                          - 1))))
    return jax.tree.map(one, tree)


# ---------------------------------------------------------------------------
# Megatron-style sequence-parallel activation constraint.
#
# The launcher installs a NamedSharding for (B, S, D) activations with the
# *sequence* dim sharded over the model axis; models call ``constrain_seq``
# on their scan carries. Effect: the per-layer activations saved by the
# remat-scan for backward are S-sharded (L × B·S·D/16 instead of L × B·S·D
# per device) and the TP output all-reduces become reduce-scatters. Without
# this, pixtral-12b train_4k peaks at 56 GB/device (EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------

_ACTIVATION_SHARDING = None  # Optional[NamedSharding] for (B, S, D)


def set_activation_sharding(sharding) -> None:
    global _ACTIVATION_SHARDING
    _ACTIVATION_SHARDING = sharding


def make_seq_parallel_sharding(mesh: Mesh, batch: int, seq: int):
    dp = data_axes(mesh)
    spec = sanitize(P(dp, "model", None), (batch, seq, 1 << 30), mesh)
    return NamedSharding(mesh, spec)


def constrain_seq(x):
    """Apply the installed sequence-parallel constraint to a (B, S, D)
    activation; identity when not configured (CPU tests, decode)."""
    if _ACTIVATION_SHARDING is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, _ACTIVATION_SHARDING)


# (B, S, W) LRU-width-sharded constraint for the RG-LRU gate outputs: with
# the gate output constrained to the same W-sharding as its input, GSPMD
# all-gathers the bf16 input once instead of all-reducing the f32 partial
# outputs of the contraction-sharded W×W matmul (4x less ICI traffic).
_LRU_GATE_SHARDING = None


def set_lru_gate_sharding(sharding) -> None:
    global _LRU_GATE_SHARDING
    _LRU_GATE_SHARDING = sharding


def make_width_sharding(mesh: Mesh, batch: int, width: int):
    dp = data_axes(mesh)
    spec = sanitize(P(dp, None, "model"), (batch, 1 << 30, width), mesh)
    return NamedSharding(mesh, spec)


def constrain_lru_gate(x):
    if _LRU_GATE_SHARDING is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, _LRU_GATE_SHARDING)
